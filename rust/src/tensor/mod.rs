//! Host tensor: a dense, row-major f32 array with shape.
//!
//! This is the currency between backend executions, the collective fabric,
//! and the optimizers. Since the NativeBackend (runtime/native.rs) runs the
//! per-rank step functions as pure-Rust kernels, the linear algebra here is
//! the compute hot path of the whole simulator:
//!
//! * `matmul` / `matmul_into` — cache-blocked, panel-packed GEMM with
//!   SIMD register tiling (tensor::simd) and `std::thread`-based row-band
//!   parallelism for large shapes (DESIGN.md §4, §11).
//! * `matmul_at_b*` / `matmul_a_bt*` — the transpose family (`Aᵀ·B`,
//!   `A·Bᵀ`) used by the backward kernels; strided views into the same
//!   blocked engine, so no transpose is ever materialized.
//! * `gemm_acc` and friends (tensor::gemm) — slice-level accumulate kernels
//!   the fused backend kernels use to sum multi-term products into one
//!   buffer without intermediate allocations. Blocking/threading parameters
//!   come from the per-shape tuning manifest (tensor::tune, `phantom tune`).
//! * `Scratch` — a reusable buffer pool for caller-owned output tensors
//!   (the engine's internal packing draws from its own global band pool).
//! * `matmul_naive` — the textbook triple loop kept as the property-test
//!   oracle for all of the above.
//! * `seed::gemm_acc_seed` — the pre-SIMD seed kernel, frozen as the CI
//!   regression-gate baseline.

pub mod gemm;
pub mod seed;
pub mod simd;
pub mod tune;

pub use gemm::{
    gemm_a_bt_acc, gemm_a_bt_acc_with, gemm_acc, gemm_acc_with, gemm_at_b_acc, gemm_at_b_acc_with,
};

use crate::util::prng::Prng;
use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

// ---------------------------------------------------------------------------
// Scratch: reusable f32 buffer pool
// ---------------------------------------------------------------------------

/// A pool of reusable f32 allocations. Kernels on the per-iteration critical
/// path acquire zeroed tensors / raw buffers from it and return them when
/// done. (GEMM panel packing no longer uses this: the blocked engine draws
/// per-band workspaces from a process-global pool in tensor::gemm, so
/// spawned bands reuse allocations across calls too.)
#[derive(Debug, Default)]
pub struct Scratch {
    free: Vec<Vec<f32>>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// A zeroed tensor of `shape`, reusing a pooled allocation when
    /// available.
    pub fn zeros(&mut self, shape: &[usize]) -> Tensor {
        let numel = shape.iter().product();
        let data = self.buf(numel);
        Tensor { shape: shape.to_vec(), data }
    }

    /// Return a tensor's allocation to the pool.
    pub fn recycle(&mut self, t: Tensor) {
        self.put(t.data);
    }

    /// A zero-filled raw buffer of exactly `len` elements.
    pub fn buf(&mut self, len: usize) -> Vec<f32> {
        let mut b = self.free.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        b
    }

    /// Return a raw buffer to the pool.
    pub fn put(&mut self, buf: Vec<f32>) {
        self.free.push(buf);
    }

    /// Number of pooled (idle) buffers — used by tests.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    /// A zeroed tensor whose allocation is drawn from the process-global
    /// bounded band pool (the same pool the GEMM engine's bands pack
    /// panels from), so per-iteration kernel outputs reuse buffers across
    /// calls instead of churning the allocator. Pair with
    /// [`Tensor::recycle`] at the value's death site; plain dropping is
    /// always safe, it just forfeits the reuse.
    pub fn zeros_pooled(shape: &[usize]) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: gemm::pooled_buf(numel) }
    }

    /// Return this tensor's allocation to the bounded band pool, where
    /// the next [`Tensor::zeros_pooled`] (or GEMM band workspace) reuses
    /// it. The pool is capped, so recycling never grows memory unbounded.
    pub fn recycle(self) {
        gemm::pooled_buf_put(self.data);
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; numel] }
    }

    /// N(0, sigma^2) initialization from a deterministic stream.
    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Prng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, sigma);
        t
    }

    // -- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.flat_index(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let i = self.flat_index(idx);
        self.data[i] = v;
    }

    fn flat_index(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len(), "index rank mismatch");
        let mut flat = 0;
        for (d, (&i, &s)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(i < s, "index {i} out of bounds for dim {d} (size {s})");
            flat = flat * s + i;
        }
        flat
    }

    // -- shape ops ----------------------------------------------------------

    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let numel: usize = shape.iter().product();
        if numel != self.data.len() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.data.len(), shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// Split along axis 1 of a 2-D tensor into `p` equal column shards.
    /// This is the activation sharding used by both TP and PP.
    pub fn col_shards(&self, p: usize) -> Result<Vec<Tensor>> {
        if self.shape.len() != 2 {
            bail!("col_shards needs a 2-D tensor, got {:?}", self.shape);
        }
        let (rows, cols) = (self.shape[0], self.shape[1]);
        if cols % p != 0 {
            bail!("cols {} not divisible by p {}", cols, p);
        }
        let w = cols / p;
        let mut datas: Vec<Vec<f32>> = (0..p).map(|_| Vec::with_capacity(rows * w)).collect();
        for r in 0..rows {
            let row = &self.data[r * cols..(r + 1) * cols];
            for (j, d) in datas.iter_mut().enumerate() {
                d.extend_from_slice(&row[j * w..(j + 1) * w]);
            }
        }
        Ok(datas
            .into_iter()
            .map(|data| Tensor { shape: vec![rows, w], data })
            .collect())
    }

    /// Inverse of `col_shards`.
    pub fn from_col_shards(shards: &[Tensor]) -> Result<Tensor> {
        if shards.is_empty() {
            bail!("no shards");
        }
        let rows = shards[0].shape[0];
        let w = shards[0].shape[1];
        for s in shards {
            if s.shape != [rows, w] {
                bail!("ragged shards: {:?} vs [{rows}, {w}]", s.shape);
            }
        }
        let p = shards.len();
        let mut data = Vec::with_capacity(rows * w * p);
        for r in 0..rows {
            for s in shards {
                data.extend_from_slice(&s.data[r * w..(r + 1) * w]);
            }
        }
        Ok(Tensor { shape: vec![rows, w * p], data })
    }

    /// Stack equal-shaped tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack of nothing");
        }
        let inner = parts[0].shape.clone();
        for t in parts {
            if t.shape != inner {
                bail!("ragged stack: {:?} vs {:?}", t.shape, inner);
            }
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&inner);
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for t in parts {
            data.extend_from_slice(&t.data);
        }
        Ok(Tensor { shape, data })
    }

    /// Slice out index `i` of the leading axis.
    pub fn unstack_at(&self, i: usize) -> Tensor {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Zero the `i`-th slice of the leading axis in place (the own-slot
    /// convention after All-Gather; see python/compile/kernels/ref.py).
    pub fn zero_slot(&mut self, i: usize) {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        self.data[i * inner..(i + 1) * inner].fill(0.0);
    }

    /// Reassemble a stacked shard tensor [p, B, m] (All-Gather output) into
    /// the full activation [B, p*m] with shard j occupying columns
    /// [j*m, (j+1)*m). Inverse of `col_shards` + `stack`.
    pub fn concat_shards_stacked(&self) -> Result<Tensor> {
        if self.shape.len() != 3 {
            bail!("concat_shards_stacked needs [p, B, m], got {:?}", self.shape);
        }
        let (p, b, m) = (self.shape[0], self.shape[1], self.shape[2]);
        let mut data = Vec::with_capacity(p * b * m);
        for r in 0..b {
            for j in 0..p {
                let src = (j * b + r) * m;
                data.extend_from_slice(&self.data[src..src + m]);
            }
        }
        Ok(Tensor { shape: vec![b, p * m], data })
    }

    /// Slice columns [start, start+width) of a 2-D tensor.
    pub fn col_slice(&self, start: usize, width: usize) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("col_slice needs a 2-D tensor, got {:?}", self.shape);
        }
        let (rows, cols) = (self.shape[0], self.shape[1]);
        if start + width > cols {
            bail!("col_slice [{start}, {}) out of bounds for {cols} cols", start + width);
        }
        let mut data = Vec::with_capacity(rows * width);
        for r in 0..rows {
            let src = r * cols + start;
            data.extend_from_slice(&self.data[src..src + width]);
        }
        Ok(Tensor { shape: vec![rows, width], data })
    }

    // -- elementwise ---------------------------------------------------------

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// self -= lr * grad   (the SGD inner loop; optimizers build on this)
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn relu(&self) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| x.max(0.0)).collect(),
        }
    }

    pub fn sq_sum(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    // -- linear algebra ------------------------------------------------------

    fn dims2(&self, op: &str) -> Result<(usize, usize)> {
        if self.shape.len() != 2 {
            bail!("{op} needs 2-D tensors, got {:?}", self.shape);
        }
        Ok((self.shape[0], self.shape[1]))
    }

    /// C = A @ B for 2-D tensors: the blocked, panel-packed, multithreaded
    /// hot path (see `gemm_acc`).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let mut out = Tensor::zeros(&self.matmul_shape(other, "matmul", false, false)?);
        self.matmul_into(other, &mut out)?;
        Ok(out)
    }

    /// C = A @ B written into a caller-provided (e.g. `Scratch`-pooled)
    /// tensor of the right shape. Overwrites `out`.
    pub fn matmul_into(&self, other: &Tensor, out: &mut Tensor) -> Result<()> {
        let shape = self.matmul_shape(other, "matmul_into", false, false)?;
        if out.shape != shape {
            bail!("matmul_into: out shape {:?} wants {:?}", out.shape, shape);
        }
        out.data.fill(0.0);
        let (m, kd) = (self.shape[0], self.shape[1]);
        let n = other.shape[1];
        gemm_acc(&self.data, m, kd, &other.data, n, &mut out.data);
        Ok(())
    }

    /// C = Aᵀ @ B without materializing the transpose (A is `self`,
    /// stored [k, m]).
    pub fn matmul_at_b(&self, other: &Tensor) -> Result<Tensor> {
        let mut out = Tensor::zeros(&self.matmul_shape(other, "matmul_at_b", true, false)?);
        self.matmul_at_b_into(other, &mut out)?;
        Ok(out)
    }

    /// C = Aᵀ @ B into a caller-provided tensor. Overwrites `out`.
    pub fn matmul_at_b_into(&self, other: &Tensor, out: &mut Tensor) -> Result<()> {
        let shape = self.matmul_shape(other, "matmul_at_b_into", true, false)?;
        if out.shape != shape {
            bail!("matmul_at_b_into: out shape {:?} wants {:?}", out.shape, shape);
        }
        out.data.fill(0.0);
        let (kd, m) = (self.shape[0], self.shape[1]);
        let n = other.shape[1];
        gemm_at_b_acc(&self.data, kd, m, &other.data, n, &mut out.data);
        Ok(())
    }

    /// C = A @ Bᵀ without materializing the transpose (B is `other`,
    /// stored [n, k]).
    pub fn matmul_a_bt(&self, other: &Tensor) -> Result<Tensor> {
        let mut out = Tensor::zeros(&self.matmul_shape(other, "matmul_a_bt", false, true)?);
        self.matmul_a_bt_into(other, &mut out)?;
        Ok(out)
    }

    /// C = A @ Bᵀ into a caller-provided tensor. Overwrites `out`.
    pub fn matmul_a_bt_into(&self, other: &Tensor, out: &mut Tensor) -> Result<()> {
        let shape = self.matmul_shape(other, "matmul_a_bt_into", false, true)?;
        if out.shape != shape {
            bail!("matmul_a_bt_into: out shape {:?} wants {:?}", out.shape, shape);
        }
        out.data.fill(0.0);
        let (m, kd) = (self.shape[0], self.shape[1]);
        let n = other.shape[0];
        gemm_a_bt_acc(&self.data, m, kd, &other.data, n, &mut out.data);
        Ok(())
    }

    /// Output shape + inner-dimension check for the matmul family.
    fn matmul_shape(
        &self,
        other: &Tensor,
        op: &str,
        t_a: bool,
        t_b: bool,
    ) -> Result<Vec<usize>> {
        let (a0, a1) = self.dims2(op)?;
        let (b0, b1) = other.dims2(op)?;
        let (m, ka) = if t_a { (a1, a0) } else { (a0, a1) };
        let (kb, n) = if t_b { (b1, b0) } else { (b0, b1) };
        if ka != kb {
            bail!("{op} inner dim mismatch: {:?} @ {:?}", self.shape, other.shape);
        }
        Ok(vec![m, n])
    }

    /// Textbook i-j-k triple loop. The reference oracle the blocked kernels
    /// are property-tested against, and the baseline the microbench
    /// speedup is measured from.
    pub fn matmul_naive(&self, other: &Tensor) -> Result<Tensor> {
        let (m, ka) = self.dims2("matmul_naive")?;
        let (kb, n) = other.dims2("matmul_naive")?;
        if ka != kb {
            bail!("matmul_naive inner dim mismatch: {:?} @ {:?}", self.shape, other.shape);
        }
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for t in 0..ka {
                    acc += self.data[i * ka + t] * other.data[t * n + j];
                }
                out.data[i * n + j] = acc;
            }
        }
        Ok(out)
    }

    /// 2-D transpose, tiled 32x32 so both source and destination are walked
    /// in cache-line-sized runs.
    pub fn transpose(&self) -> Result<Tensor> {
        let (m, n) = self.dims2("transpose")?;
        const TB: usize = 32;
        let mut out = Tensor::zeros(&[n, m]);
        for ib in (0..m).step_by(TB) {
            let ie = (ib + TB).min(m);
            for jb in (0..n).step_by(TB) {
                let je = (jb + TB).min(n);
                for i in ib..ie {
                    for j in jb..je {
                        out.data[j * m + i] = self.data[i * n + j];
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{assert_close, quickcheck};

    #[test]
    fn construct_and_index() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set(&[1, 2], 5.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
        assert!(a.matmul_naive(&b).is_err());
        // but the transpose variants accept exactly these shapes
        assert!(a.matmul_a_bt(&b).is_ok());
        assert!(a.matmul_at_b(&b).is_ok());
    }

    #[test]
    fn blocked_matmul_matches_naive_ragged() {
        // The property the whole native backend rests on: the blocked,
        // packed, (potentially) threaded kernel agrees with the textbook
        // triple loop on ragged, non-power-of-two shapes.
        quickcheck("blocked matmul == naive", |rng| {
            let m = rng.int_in(1, 40) as usize;
            let k = rng.int_in(1, 40) as usize;
            let n = rng.int_in(1, 40) as usize;
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let fast = a.matmul(&b).unwrap();
            let slow = a.matmul_naive(&b).unwrap();
            assert_close(fast.data(), slow.data(), 1e-5, 1e-6)
        });
    }

    #[test]
    fn blocked_matmul_matches_naive_across_block_boundaries() {
        // Dimensions straddling MR / KC / JC block edges, large enough to
        // engage the row-band threading path.
        let mut rng = Prng::new(77);
        for (m, k, n) in [(70, 300, 530), (257, 513, 65), (129, 64, 515)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let fast = a.matmul(&b).unwrap();
            let slow = a.matmul_naive(&b).unwrap();
            assert_close(fast.data(), slow.data(), 1e-4, 1e-5)
                .unwrap_or_else(|e| panic!("({m},{k},{n}): {e}"));
        }
    }

    #[test]
    fn transpose_family_matches_compositions() {
        quickcheck("A^T@B and A@B^T match transpose compositions", |rng| {
            let m = rng.int_in(1, 12) as usize;
            let k = rng.int_in(1, 12) as usize;
            let n = rng.int_in(1, 12) as usize;
            let a = Tensor::randn(&[k, m], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let atb = a.matmul_at_b(&b).unwrap();
            let reference = a.transpose().unwrap().matmul_naive(&b).unwrap();
            assert_close(atb.data(), reference.data(), 1e-5, 1e-6)?;

            let c = Tensor::randn(&[m, k], 1.0, rng);
            let d = Tensor::randn(&[n, k], 1.0, rng);
            let abt = c.matmul_a_bt(&d).unwrap();
            let reference = c.matmul_naive(&d.transpose().unwrap()).unwrap();
            assert_close(abt.data(), reference.data(), 1e-5, 1e-6)
        });
    }

    #[test]
    fn gemm_acc_accumulates() {
        let a = Tensor::filled(&[2, 3], 1.0);
        let b = Tensor::filled(&[3, 2], 2.0);
        let mut out = vec![10.0f32; 4];
        gemm_acc(a.data(), 2, 3, b.data(), 2, &mut out);
        assert_eq!(out, vec![16.0; 4]); // 10 + 1*2*3
    }

    #[test]
    fn matmul_into_reuses_scratch() {
        let mut scratch = Scratch::new();
        let mut rng = Prng::new(5);
        let a = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let mut out = scratch.zeros(&[6, 5]);
        a.matmul_into(&b, &mut out).unwrap();
        assert_close(out.data(), a.matmul_naive(&b).unwrap().data(), 1e-5, 1e-6).unwrap();
        scratch.recycle(out);
        assert_eq!(scratch.pooled(), 1);
        // Second acquisition reuses the pooled allocation and is zeroed.
        let out2 = scratch.zeros(&[5, 4]);
        assert_eq!(scratch.pooled(), 0);
        assert!(out2.data().iter().all(|&x| x == 0.0));
        // Shape mismatch is rejected.
        let mut bad = Tensor::zeros(&[3, 3]);
        assert!(a.matmul_into(&b, &mut bad).is_err());
    }

    #[test]
    fn shard_roundtrip() {
        let mut rng = Prng::new(3);
        let t = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let shards = t.col_shards(4).unwrap();
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[0].shape(), &[4, 2]);
        let back = Tensor::from_col_shards(&shards).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn col_slice_agrees_with_col_shards() {
        let mut rng = Prng::new(13);
        let t = Tensor::randn(&[5, 12], 1.0, &mut rng);
        let shards = t.col_shards(4).unwrap();
        for (j, s) in shards.iter().enumerate() {
            assert_eq!(&t.col_slice(j * 3, 3).unwrap(), s);
        }
        assert!(t.col_slice(10, 3).is_err());
    }

    #[test]
    fn concat_shards_stacked_inverts_shard_stack() {
        let mut rng = Prng::new(21);
        let t = Tensor::randn(&[6, 8], 1.0, &mut rng);
        let stacked = Tensor::stack(&t.col_shards(4).unwrap()).unwrap();
        assert_eq!(stacked.concat_shards_stacked().unwrap(), t);
    }

    #[test]
    fn stack_unstack_zero_slot() {
        let a = Tensor::filled(&[2, 2], 1.0);
        let b = Tensor::filled(&[2, 2], 2.0);
        let mut s = Tensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape(), &[2, 2, 2]);
        assert_eq!(s.unstack_at(1), b);
        s.zero_slot(0);
        assert_eq!(s.unstack_at(0), Tensor::zeros(&[2, 2]));
        assert_eq!(s.unstack_at(1), b);
    }

    #[test]
    fn transpose_involution() {
        quickcheck("transpose twice is identity", |rng| {
            let m = rng.int_in(1, 40) as usize;
            let n = rng.int_in(1, 40) as usize;
            let t = Tensor::randn(&[m, n], 1.0, rng);
            let tt = t.transpose().unwrap().transpose().unwrap();
            assert_close(t.data(), tt.data(), 0.0, 0.0)
        });
    }

    #[test]
    fn matmul_transpose_property() {
        // (A @ B)^T == B^T @ A^T
        quickcheck("matmul transpose identity", |rng| {
            let m = rng.int_in(1, 6) as usize;
            let k = rng.int_in(1, 6) as usize;
            let n = rng.int_in(1, 6) as usize;
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let lhs = a.matmul(&b).unwrap().transpose().unwrap();
            let rhs = b.transpose().unwrap().matmul(&a.transpose().unwrap()).unwrap();
            assert_close(lhs.data(), rhs.data(), 1e-5, 1e-6)
        });
    }

    #[test]
    fn elementwise_ops() {
        let mut a = Tensor::filled(&[3], 1.0);
        let b = Tensor::filled(&[3], 2.0);
        a.add_assign(&b);
        assert_eq!(a.data(), &[3.0, 3.0, 3.0]);
        a.axpy(-0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.0, 2.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[4.0, 4.0, 4.0]);
        let r = Tensor::from_vec(&[2], vec![-1.0, 1.0]).unwrap().relu();
        assert_eq!(r.data(), &[0.0, 1.0]);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Prng::new(11);
        let t = Tensor::randn(&[100, 100], 0.5, &mut rng);
        let mean: f64 = t.data().iter().map(|&x| x as f64).sum::<f64>() / 10_000.0;
        let var = t.sq_sum() / 10_000.0 - mean * mean;
        assert!(mean.abs() < 0.02);
        assert!((var - 0.25).abs() < 0.02);
    }
}
