//! Model state: partitioning math, parameter shapes, initialization and
//! parameter counting for phantom-parallel and tensor-parallel FFNs.
//!
//! Initialization is deterministic per (seed, mode, layer, rank) so a p-rank
//! distributed run and the single-rank dense-equivalent oracle construct
//! bit-identical weights — the integration tests rely on this.

use anyhow::Result;

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// Per-rank phantom-parallel parameters (paper Sec. IV):
/// for each layer l: L [m, m], C [m, k], D [p, k, m] (own slot zero), b [m].
#[derive(Debug, Clone)]
pub struct PhantomRankParams {
    pub rank: usize,
    pub p: usize,
    /// Shard width m = n/p.
    pub m: usize,
    pub k: usize,
    pub locals: Vec<Tensor>,
    pub compressors: Vec<Tensor>,
    pub decompressors: Vec<Tensor>,
    pub biases: Vec<Tensor>,
}

/// Per-rank tensor-parallel parameters: for each layer l the column shard
/// W [n, m] and bias shard b [m].
#[derive(Debug, Clone)]
pub struct TpRankParams {
    pub rank: usize,
    pub p: usize,
    pub m: usize,
    pub weights: Vec<Tensor>,
    pub biases: Vec<Tensor>,
}

/// Weight init scales: He-style fan-in gains, with the phantom remote path
/// normalized by the source count so the local and aggregate-remote
/// contributions to z have comparable variance at init. This matters for
/// the convergence experiments: the compressor-decompressor product is a
/// rank-k bottleneck that learns very slowly from tiny init (deep-linear
/// dynamics), and the paper's fixed-loss comparisons presume PP trains
/// readily.
fn local_sigma(m: usize) -> f32 {
    (1.0 / m as f32).sqrt()
}

fn compressor_sigma(m: usize) -> f32 {
    (2.0 / m as f32).sqrt()
}

fn decompressor_sigma(k: usize, p: usize) -> f32 {
    (1.0 / (k * (p - 1).max(1)) as f32).sqrt()
}

fn tp_sigma(n: usize) -> f32 {
    (2.0 / n as f32).sqrt()
}

const BIAS_SIGMA: f32 = 0.01;

impl PhantomRankParams {
    /// Deterministic init: stream derived from (seed, layer, rank, role).
    pub fn init(model: &ModelConfig, p: usize, rank: usize, seed: u64) -> Result<Self> {
        model.validate(p)?;
        let m = model.n / p;
        let k = model.k;
        let mut locals = Vec::new();
        let mut compressors = Vec::new();
        let mut decompressors = Vec::new();
        let mut biases = Vec::new();
        for l in 0..model.layers {
            locals.push(Tensor::randn(
                &[m, m],
                local_sigma(m),
                &mut stream(seed, 0, l, rank, 0),
            ));
            compressors.push(Tensor::randn(
                &[m, k],
                compressor_sigma(m),
                &mut stream(seed, 0, l, rank, 1),
            ));
            // D[src] on this rank decompresses the phantom layer received
            // from `src`; stream keyed by (src -> rank) so the dense oracle
            // can rebuild the identical matrix. Own slot stays zero.
            let mut d = Tensor::zeros(&[p, k, m]);
            for src in 0..p {
                if src == rank {
                    continue;
                }
                let block = Tensor::randn(
                    &[k, m],
                    decompressor_sigma(k, p),
                    &mut dstream(seed, l, rank, src),
                );
                let off = src * k * m;
                d.data_mut()[off..off + k * m].copy_from_slice(block.data());
            }
            decompressors.push(d);
            biases.push(Tensor::randn(&[m], BIAS_SIGMA, &mut stream(seed, 0, l, rank, 2)));
        }
        Ok(PhantomRankParams {
            rank,
            p,
            m,
            k,
            locals,
            compressors,
            decompressors,
            biases,
        })
    }

    pub fn layers(&self) -> usize {
        self.locals.len()
    }

    /// Parameters held by this rank.
    pub fn param_count(&self) -> u64 {
        let per_layer = (self.m * self.m)                 // L
            + (self.m * self.k)                           // C
            + ((self.p - 1) * self.k * self.m)            // D (own slot frozen)
            + self.m; // b
        (per_layer * self.layers()) as u64
    }

    /// Flat list of (name, tensor) for optimizers/checkpoints. The D
    /// tensors include the frozen zero slot; its gradient is always zero so
    /// optimizers never move it (asserted in tests).
    pub fn named_tensors(&mut self) -> Vec<(String, &mut Tensor)> {
        let mut out = Vec::new();
        let l = self.locals.len();
        for (i, t) in self.locals.iter_mut().enumerate() {
            out.push((format!("L{i}"), t));
        }
        for (i, t) in self.compressors.iter_mut().enumerate() {
            out.push((format!("C{i}"), t));
        }
        for (i, t) in self.decompressors.iter_mut().enumerate() {
            out.push((format!("D{i}"), t));
        }
        for (i, t) in self.biases.iter_mut().enumerate() {
            out.push((format!("b{i}"), t));
        }
        debug_assert_eq!(out.len(), 4 * l);
        out
    }
}

impl TpRankParams {
    /// Column shard of the full W. Streams are keyed by (layer, GLOBAL
    /// column), not by rank, so the full matrix — and therefore the TP
    /// training trajectory and its iterations-to-loss — is IDENTICAL for
    /// every p (paper Table I: the TP epoch count is p-independent).
    pub fn init(model: &ModelConfig, p: usize, rank: usize, seed: u64) -> Result<Self> {
        model.validate(p)?;
        let n = model.n;
        let m = n / p;
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in 0..model.layers {
            let mut w = Tensor::zeros(&[n, m]);
            let mut col = vec![0.0f32; n];
            for c in 0..m {
                let global_col = rank * m + c;
                let mut rng = stream(seed, 1, l, global_col, 0);
                rng.fill_normal(&mut col, tp_sigma(n));
                for (r, &v) in col.iter().enumerate() {
                    w.data_mut()[r * m + c] = v;
                }
            }
            weights.push(w);
            let mut b = Tensor::zeros(&[m]);
            for c in 0..m {
                let global_col = rank * m + c;
                b.data_mut()[c] = stream(seed, 1, l, global_col, 2).normal_f32() * BIAS_SIGMA;
            }
            biases.push(b);
        }
        Ok(TpRankParams { rank, p, m, weights, biases })
    }

    pub fn layers(&self) -> usize {
        self.weights.len()
    }

    pub fn param_count(&self) -> u64 {
        let n = self.m * self.p;
        ((n * self.m + self.m) * self.layers()) as u64
    }

    pub fn named_tensors(&mut self) -> Vec<(String, &mut Tensor)> {
        let mut out = Vec::new();
        for (i, t) in self.weights.iter_mut().enumerate() {
            out.push((format!("W{i}"), t));
        }
        for (i, t) in self.biases.iter_mut().enumerate() {
            out.push((format!("b{i}"), t));
        }
        out
    }
}

/// Derive the deterministic stream for a parameter tensor.
/// `mode`: 0 = phantom, 1 = tensor-parallel. `role`: 0 = weight, 1 =
/// compressor, 2 = bias.
fn stream(seed: u64, mode: u64, layer: usize, rank: usize, role: u64) -> Prng {
    let tag = (mode << 48)
        ^ ((layer as u64) << 32)
        ^ ((rank as u64) << 16)
        ^ (role << 8)
        ^ 0x5EED;
    Prng::new(seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
}

/// Stream for a decompressor block (layer, dst rank, src rank).
fn dstream(seed: u64, layer: usize, dst: usize, src: usize) -> Prng {
    let tag = (2u64 << 48) ^ ((layer as u64) << 32) ^ ((dst as u64) << 16) ^ (src as u64);
    Prng::new(seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
}

// ---------------------------------------------------------------------------
// Model-size accounting (paper Table I columns)
// ---------------------------------------------------------------------------

/// Total TP model size: L * (n^2 + n). Independent of p.
pub fn tp_model_params(n: usize, layers: usize) -> u64 {
    (layers * (n * n + n)) as u64
}

/// Total PP model size across all ranks:
/// L * p * (m^2 + m*k + (p-1)*k*m + m) with m = n/p.
pub fn pp_model_params(n: usize, layers: usize, p: usize, k: usize) -> u64 {
    let m = n / p;
    (layers * p * (m * m + m * k + (p - 1) * k * m + m)) as u64
}

/// Dense-equivalent of the sharded phantom model, evaluated on one rank.
/// Used by integration tests (invariant 1 of DESIGN.md §6) and by the
/// pure-Rust fallback path.
pub struct DensePhantomOracle {
    pub p: usize,
    pub m: usize,
    pub k: usize,
    /// Per rank copies of the rank params, in rank order.
    pub ranks: Vec<PhantomRankParams>,
}

impl DensePhantomOracle {
    pub fn init(model: &ModelConfig, p: usize, seed: u64) -> Result<Self> {
        let ranks = (0..p)
            .map(|r| PhantomRankParams::init(model, p, r, seed))
            .collect::<Result<Vec<_>>>()?;
        Ok(DensePhantomOracle { p, m: model.n / p, k: model.k, ranks })
    }

    /// Wrap existing per-rank parameters (e.g. loaded from a checkpoint)
    /// as the dense-equivalent oracle. `ranks` must be the full rank set
    /// in rank order with consistent geometry.
    pub fn from_ranks(ranks: Vec<PhantomRankParams>) -> Result<Self> {
        let first = ranks.first().ok_or_else(|| anyhow::anyhow!("empty rank set"))?;
        let (p, m, k) = (first.p, first.m, first.k);
        if ranks.len() != p {
            anyhow::bail!("got {} ranks for p={p}", ranks.len());
        }
        for (i, r) in ranks.iter().enumerate() {
            if r.rank != i || r.p != p || r.m != m || r.k != k {
                anyhow::bail!(
                    "rank {i}: inconsistent shard (rank={}, p={}, m={}, k={})",
                    r.rank,
                    r.p,
                    r.m,
                    r.k
                );
            }
            if r.layers() != first.layers() {
                anyhow::bail!("rank {i}: {} layers vs {}", r.layers(), first.layers());
            }
        }
        Ok(DensePhantomOracle { p, m, k, ranks })
    }

    /// Forward through all layers on the full width; returns y_out [B, n].
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let mut y = x.clone();
        let layers = self.ranks[0].layers();
        for l in 0..layers {
            y = self.forward_layer(l, &y)?;
        }
        Ok(y)
    }

    fn forward_layer(&self, l: usize, y_full: &Tensor) -> Result<Tensor> {
        let shards = y_full.col_shards(self.p)?;
        // phantom activations per source rank
        let gs: Vec<Tensor> = (0..self.p)
            .map(|j| shards[j].matmul(&self.ranks[j].compressors[l]))
            .collect::<Result<_>>()?;
        let mut outs = Vec::with_capacity(self.p);
        for j in 0..self.p {
            let mut z = shards[j].matmul(&self.ranks[j].locals[l])?;
            for (src, g) in gs.iter().enumerate() {
                if src == j {
                    continue;
                }
                let d = self.ranks[j].decompressors[l].unstack_at(src); // [k, m]
                z.add_assign(&g.matmul(&d)?);
            }
            // bias + relu
            let b = &self.ranks[j].biases[l];
            let bsz = z.shape()[0];
            for r in 0..bsz {
                for c in 0..self.m {
                    let v = z.at(&[r, c]) + b.data()[c];
                    z.set(&[r, c], v.max(0.0));
                }
            }
            outs.push(z);
        }
        Tensor::from_col_shards(&outs)
    }
}

/// Reassemble the full TP weight matrices [n, n] and biases [n] from the
/// per-rank column shards (rank order). The exact inverse of `TpRankParams`
/// column sharding; checkpoint re-sharding gathers through this.
pub fn assemble_tp_dense(shards: &[TpRankParams]) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    let first = shards.first().ok_or_else(|| anyhow::anyhow!("empty shard set"))?;
    let (p, m) = (first.p, first.m);
    let n = p * m;
    if shards.len() != p {
        anyhow::bail!("got {} shards for p={p}", shards.len());
    }
    let layers = first.layers();
    for (j, s) in shards.iter().enumerate() {
        if s.rank != j || s.p != p || s.m != m || s.layers() != layers {
            anyhow::bail!("shard {j}: inconsistent geometry");
        }
        for l in 0..layers {
            if s.weights[l].shape() != [n, m] {
                anyhow::bail!(
                    "shard {j} layer {l}: weight {:?}, want [{n}, {m}]",
                    s.weights[l].shape()
                );
            }
        }
    }
    let mut weights = Vec::with_capacity(layers);
    let mut biases = Vec::with_capacity(layers);
    for l in 0..layers {
        let cols: Vec<Tensor> = shards.iter().map(|s| s.weights[l].clone()).collect();
        weights.push(Tensor::from_col_shards(&cols)?);
        let mut b = Tensor::zeros(&[n]);
        for (j, s) in shards.iter().enumerate() {
            b.data_mut()[j * m..(j + 1) * m].copy_from_slice(s.biases[l].data());
        }
        biases.push(b);
    }
    Ok((weights, biases))
}

/// Forward an input [B, n] through dense layer stacks y = relu(y W + b) —
/// the host-side reference for TP models (checkpoint verify / re-sharding
/// equivalence proofs).
pub fn tp_dense_forward(weights: &[Tensor], biases: &[Tensor], x: &Tensor) -> Result<Tensor> {
    let mut y = x.clone();
    for (w, b) in weights.iter().zip(biases) {
        let mut z = y.matmul(w)?;
        let n = b.numel();
        for row in z.data_mut().chunks_mut(n) {
            for (v, &bv) in row.iter_mut().zip(b.data()) {
                *v = (*v + bv).max(0.0);
            }
        }
        y = z;
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n: usize, layers: usize, k: usize) -> ModelConfig {
        ModelConfig { n, layers, k }
    }

    #[test]
    fn table1_model_sizes() {
        // Paper Table I, n = 16384, L = 2 (sizes in millions, rounded).
        assert_eq!(tp_model_params(16_384, 2) / 1_000_000, 536); // "537M"
        let cases = [
            (8usize, 16usize, 71u64),
            (16, 6, 36),  // "37M"
            (32, 4, 21),
            (64, 2, 12),  // "13M"
            (128, 2, 13),
            (256, 4, 35), // "36M"
        ];
        for (p, k, want_m) in cases {
            let got = pp_model_params(16_384, 2, p, k) / 1_000_000;
            assert!(
                got == want_m || got == want_m + 1 || got + 1 == want_m,
                "p={p} k={k}: got {got}M want ~{want_m}M"
            );
        }
    }

    #[test]
    fn pp_smaller_than_tp_iff_eqn8() {
        let n = 1024;
        for p in [2usize, 4, 8, 16] {
            let m = n / p;
            for k in [1, m / 4, m - m / p - 1, m - m / p, m - 1] {
                if k == 0 || k >= m {
                    continue;
                }
                let pp = pp_model_params(n, 2, p, k);
                let tp = tp_model_params(n, 2);
                let eqn8 = (k as f64) < m as f64 * (1.0 - 1.0 / p as f64);
                // Ignore the +n bias-count wrinkle by comparing weight-only
                // when right at the boundary.
                if eqn8 {
                    assert!(pp < tp, "p={p} k={k}: pp={pp} tp={tp}");
                }
            }
        }
    }

    #[test]
    fn init_is_deterministic_and_rank_distinct() {
        let model = cfg(64, 2, 4);
        let a = PhantomRankParams::init(&model, 4, 1, 7).unwrap();
        let b = PhantomRankParams::init(&model, 4, 1, 7).unwrap();
        assert_eq!(a.locals[0], b.locals[0]);
        assert_eq!(a.decompressors[1], b.decompressors[1]);
        let c = PhantomRankParams::init(&model, 4, 2, 7).unwrap();
        assert_ne!(a.locals[0], c.locals[0]);
        let d = PhantomRankParams::init(&model, 4, 1, 8).unwrap();
        assert_ne!(a.locals[0], d.locals[0]);
    }

    #[test]
    fn decompressor_own_slot_is_zero() {
        let model = cfg(64, 2, 4);
        for rank in 0..4 {
            let params = PhantomRankParams::init(&model, 4, rank, 3).unwrap();
            for l in 0..2 {
                let own = params.decompressors[l].unstack_at(rank);
                assert!(own.data().iter().all(|&x| x == 0.0), "rank {rank} layer {l}");
                // and at least one other slot is nonzero
                let other = params.decompressors[l].unstack_at((rank + 1) % 4);
                assert!(other.data().iter().any(|&x| x != 0.0));
            }
        }
    }

    #[test]
    fn param_count_matches_tensors() {
        let model = cfg(64, 3, 4);
        let mut params = PhantomRankParams::init(&model, 4, 0, 1).unwrap();
        let m = 16usize;
        let counted: usize = params
            .named_tensors()
            .iter()
            .map(|(name, t)| {
                if name.starts_with('D') {
                    // exclude the frozen own slot from the logical count
                    t.numel() - 4 * 0 - (4 - 3) * t.numel() / 4
                } else {
                    t.numel()
                }
            })
            .sum();
        assert_eq!(counted as u64, params.param_count());
        assert_eq!(params.param_count(), (3 * (m * m + m * 4 + 3 * 4 * m + m)) as u64);
    }

    #[test]
    fn tp_params_deterministic() {
        let model = cfg(64, 2, 0);
        let a = TpRankParams::init(&model, 4, 2, 9).unwrap();
        let b = TpRankParams::init(&model, 4, 2, 9).unwrap();
        assert_eq!(a.weights[1], b.weights[1]);
        assert_eq!(a.param_count(), 2 * (64 * 16 + 16) as u64);
    }

    #[test]
    fn tp_full_matrix_independent_of_p() {
        // The assembled full W must be identical whether sharded 2-way or
        // 8-way (paper: TP iterations-to-loss is p-independent).
        let model = cfg(64, 2, 0);
        let assemble = |p: usize| -> Tensor {
            let shards: Vec<Tensor> = (0..p)
                .map(|r| TpRankParams::init(&model, p, r, 5).unwrap().weights[0].clone())
                .collect();
            // weights are [n, m] column shards; reassemble columns
            let n = 64;
            let m = n / p;
            let mut full = Tensor::zeros(&[n, n]);
            for (j, s) in shards.iter().enumerate() {
                for r in 0..n {
                    for c in 0..m {
                        full.set(&[r, j * m + c], s.at(&[r, c]));
                    }
                }
            }
            full
        };
        let w2 = assemble(2);
        let w8 = assemble(8);
        assert_eq!(w2, w8);
    }

    #[test]
    fn assemble_tp_dense_inverts_column_sharding() {
        let model = cfg(64, 2, 0);
        let p = 4;
        let shards: Vec<TpRankParams> =
            (0..p).map(|r| TpRankParams::init(&model, p, r, 5).unwrap()).collect();
        let (weights, biases) = assemble_tp_dense(&shards).unwrap();
        assert_eq!(weights[0].shape(), &[64, 64]);
        assert_eq!(biases[0].shape(), &[64]);
        let m = 16;
        for (j, s) in shards.iter().enumerate() {
            for l in 0..2 {
                for r in [0usize, 17, 63] {
                    for c in 0..m {
                        assert_eq!(
                            weights[l].at(&[r, j * m + c]),
                            s.weights[l].at(&[r, c]),
                            "layer {l} shard {j}"
                        );
                    }
                }
                for c in 0..m {
                    assert_eq!(biases[l].data()[j * m + c], s.biases[l].data()[c]);
                }
            }
        }
        // dense forward == concatenated per-shard forward
        let mut rng = Prng::new(2);
        let x = Tensor::randn(&[3, 64], 1.0, &mut rng);
        let dense = tp_dense_forward(&weights, &biases, &x).unwrap();
        let mut y = x;
        for l in 0..2 {
            let mut outs = Vec::new();
            for s in &shards {
                let mut z = y.matmul(&s.weights[l]).unwrap();
                for row in z.data_mut().chunks_mut(m) {
                    for (v, &bv) in row.iter_mut().zip(s.biases[l].data()) {
                        *v = (*v + bv).max(0.0);
                    }
                }
                outs.push(z);
            }
            y = Tensor::from_col_shards(&outs).unwrap();
        }
        for (a, b) in dense.data().iter().zip(y.data()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn oracle_from_ranks_validates_and_matches_init() {
        let model = cfg(32, 2, 3);
        let ranks: Vec<PhantomRankParams> =
            (0..4).map(|r| PhantomRankParams::init(&model, 4, r, 5).unwrap()).collect();
        let wrapped = DensePhantomOracle::from_ranks(ranks.clone()).unwrap();
        let fresh = DensePhantomOracle::init(&model, 4, 5).unwrap();
        let mut rng = Prng::new(8);
        let x = Tensor::randn(&[2, 32], 1.0, &mut rng);
        assert_eq!(wrapped.forward(&x).unwrap(), fresh.forward(&x).unwrap());
        // out-of-order ranks are rejected
        let mut bad = ranks;
        bad.swap(0, 1);
        assert!(DensePhantomOracle::from_ranks(bad).is_err());
        assert!(DensePhantomOracle::from_ranks(Vec::new()).is_err());
    }

    #[test]
    fn dense_oracle_runs() {
        let model = cfg(32, 2, 3);
        let oracle = DensePhantomOracle::init(&model, 4, 5).unwrap();
        let mut rng = Prng::new(1);
        let x = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let y = oracle.forward(&x).unwrap();
        assert_eq!(y.shape(), &[4, 32]);
        // relu output is non-negative
        assert!(y.data().iter().all(|&v| v >= 0.0));
        assert!(y.data().iter().any(|&v| v > 0.0));
    }
}
