//! Planner integration tests (ISSUE 7 tentpole validation).
//!
//! * ranking-holds: the predicted-best feasible cell of a small sweep
//!   spanning TP and PP, when actually trained through the measured
//!   simulator, consumes less energy per step than the predicted-worst
//!   cell; sweep + predictions + measurements + verdict land in
//!   BENCH_plan.json at the repo root (same convention as the other
//!   BENCH_* trajectories).
//! * calibration round-trip: fitting on the committed `ci/bench_seed`
//!   fixture recovers the constants the fixture was stamped from.
//! * the missing-fixture path is a logged fallback, not an error.

use std::path::PathBuf;

use phantom::config::Parallelism;
use phantom::perfmodel::calib::{Calibration, CalibSource, DEFAULT_CALIB_PATH};
use phantom::perfmodel::plan::{
    plan, report_json, validate, CellOutcome, Objective, PlanSpace, ValidateOptions,
};
use phantom::perfmodel::GemmModel;
use phantom::simnet::NetworkProfile;
use phantom::util::json::{write_json, Json};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn fixture_calibration() -> Calibration {
    let c = Calibration::load_or_default(&repo_root().join(DEFAULT_CALIB_PATH));
    assert!(
        matches!(c.source, CalibSource::Measured(_)),
        "committed fixture must load as measured: {:?}",
        c.warnings
    );
    c
}

/// A sweep the measured simulator can run in seconds: tiny model, both
/// modes, two p choices — 4 feasible cells (>= 3, across TP and PP).
fn small_space() -> PlanSpace {
    PlanSpace {
        n: 64,
        layers: 2,
        modes: vec![Parallelism::Phantom, Parallelism::Tensor],
        p_choices: vec![2, 4],
        dp_choices: vec![1],
        k_choices: vec![4],
        batch_choices: vec![8],
        linger_choices_s: vec![0.0],
    }
}

#[test]
fn predicted_ranking_holds_when_measured() {
    let calib = fixture_calibration();
    let space = small_space();
    let report = plan(&space, Objective::TrainJPerStep, None, &calib).unwrap();

    let feasible: Vec<_> = report
        .cells
        .iter()
        .filter(|(_, o)| matches!(o, CellOutcome::Priced(_)))
        .collect();
    assert!(feasible.len() >= 3, "need >= 3 sweep cells, got {}", feasible.len());
    assert!(
        feasible.iter().any(|(c, _)| c.mode == Parallelism::Phantom)
            && feasible.iter().any(|(c, _)| c.mode == Parallelism::Tensor),
        "sweep must span TP and PP"
    );

    // Run predicted-best and predicted-worst through the real driver.
    let opts = ValidateOptions { iters: 4, ..Default::default() };
    let verdict = validate(&report, &space, &opts).unwrap();
    assert!(verdict.best.measured_j > 0.0 && verdict.worst.measured_j > 0.0);
    assert!(
        verdict.ranking_holds,
        "predicted-best {} measured {} J/step must beat predicted-worst {} measured {} J/step",
        verdict.best.cell.label(),
        verdict.best.measured_j,
        verdict.worst.cell.label(),
        verdict.worst.measured_j
    );

    // Record the full trajectory like the other BENCH_* files.
    let out = repo_root().join("BENCH_plan.json");
    let payload = report_json(&report, &calib, Some(&verdict));
    write_json(&out, &payload).unwrap();
    let back = phantom::util::json::read_json(&out).unwrap();
    assert_eq!(back.get("ranking_holds"), &Json::Bool(true));
    assert_eq!(back.get("sweep").as_arr().unwrap().len(), report.cells.len());
    assert!(back.get("measured_best").get("measured_j").as_f64().unwrap() > 0.0);
}

#[test]
fn serve_objective_prices_and_plans() {
    // The serving objective plans over linger choices with dp pinned to 1;
    // the cheapest cell must be strictly cheaper per query than the most
    // expensive one (the sweep is not degenerate).
    let calib = fixture_calibration();
    let mut space = small_space();
    space.linger_choices_s = vec![0.0, 2e-3];
    let report = plan(&space, Objective::ServeJPerQuery, None, &calib).unwrap();
    assert!(report.feasible_count() >= 3);
    let best = report.cells[report.best.unwrap()].1.prediction().unwrap();
    let worst = report.cells[report.worst.unwrap()].1.prediction().unwrap();
    assert!(best.j_per_unit < worst.j_per_unit);
    assert!(report.cells.iter().all(|(c, _)| c.dp == 1));
}

#[test]
fn serve_objective_ranking_holds_when_measured() {
    // ROADMAP item 1 leftover: only the train objective was ever
    // measured-ranked. Run the serve objective's predicted-best and
    // predicted-worst cells through the real serving stack (pool +
    // batcher + loadgen) and demand the predicted order survives
    // measurement — the same gate the CI plan smoke now applies.
    let calib = fixture_calibration();
    let mut space = small_space();
    space.linger_choices_s = vec![0.0, 2e-3];
    let report = plan(&space, Objective::ServeJPerQuery, None, &calib).unwrap();
    assert!(report.feasible_count() >= 3);

    let opts = ValidateOptions { queries: 64, ..Default::default() };
    let verdict = validate(&report, &space, &opts).unwrap();
    assert!(verdict.best.measured_j > 0.0 && verdict.worst.measured_j > 0.0);
    assert!(
        verdict.ranking_holds,
        "predicted-best {} measured {} J/query must beat predicted-worst {} measured {} J/query",
        verdict.best.cell.label(),
        verdict.best.measured_j,
        verdict.worst.cell.label(),
        verdict.worst.measured_j
    );
}

#[test]
fn committed_fixture_round_trips_the_stamped_constants() {
    // The fixture's rows are stamped from the frontier constants (see
    // ci/bench_seed/README.md), so the fit must give them back.
    let calib = fixture_calibration();
    assert!(calib.warnings.is_empty(), "full fixture must fit cleanly: {:?}", calib.warnings);

    let g = GemmModel::frontier();
    assert!((calib.gemm.peak_flops - g.peak_flops).abs() / g.peak_flops < 0.01);
    assert!(
        (calib.gemm.full_eff_dim - g.full_eff_dim).abs() / g.full_eff_dim < 0.15,
        "knee {} vs {}",
        calib.gemm.full_eff_dim,
        g.full_eff_dim
    );
    assert!((calib.gemm.launch_overhead_s - g.launch_overhead_s).abs() < 1e-12);

    let net = NetworkProfile::frontier();
    for (got, want) in [
        (calib.net.broadcast, net.broadcast),
        (calib.net.all_reduce, net.all_reduce),
        (calib.net.all_gather, net.all_gather),
        (calib.net.reduce_scatter, net.reduce_scatter),
    ] {
        assert!((got.c1 - want.c1).abs() / want.c1 < 0.01, "{got:?} vs {want:?}");
        assert!((got.c2 - want.c2).abs() / want.c2 < 0.01, "{got:?} vs {want:?}");
    }

    assert!((calib.power.busy_w - 560.0).abs() < 1e-6);
    assert!((calib.power.idle_w - 90.0).abs() < 1e-6);
}

#[test]
fn missing_fixture_is_a_logged_fallback_and_still_plans() {
    let calib = Calibration::load_or_default(&repo_root().join("ci/bench_seed/NOPE.json"));
    assert_eq!(calib.source, CalibSource::Defaults);
    assert_eq!(calib.warnings.len(), 1);
    // The planner runs fine on the defaults.
    let report = plan(&small_space(), Objective::TrainJPerStep, None, &calib).unwrap();
    assert!(report.best.is_some());
}
