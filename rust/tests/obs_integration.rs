//! Obs-subsystem integration (DESIGN.md §13): traced runs must attribute
//! every Joule — per-category energy plus the untraced bucket equals the
//! exact ledger energy to 1e-9 relative error on the quickstart TP and PP
//! configs — the exported timeline must be valid Chrome trace-event JSON,
//! and a traced server must feed its live metrics registry.

use phantom::config::{preset, Parallelism, ServeConfig};
use phantom::coordinator::{train_with, TrainOptions};
use phantom::obs::trace::{chrome_trace, validate_trace, Track};
use phantom::runtime::ExecServer;
use phantom::serve::{PoolOptions, Server};
use phantom::tensor::Tensor;
use phantom::util::json::Json;
use phantom::util::prng::Prng;

#[test]
fn traced_train_attributes_every_joule_tp_and_pp() {
    for mode in [Parallelism::Tensor, Parallelism::Phantom] {
        let mut cfg = preset("quickstart", mode).unwrap();
        cfg.train.max_iters = 4;
        cfg.train.target_loss = None;
        let server = ExecServer::for_run(&cfg).unwrap();
        let opts = TrainOptions { trace: true, ..Default::default() };
        let report = train_with(&cfg, &server, opts).unwrap();
        let power = cfg.hardware.power;

        assert_eq!(report.per_rank.len(), cfg.world());
        assert!(report.host_trace.is_some(), "traced run carries a host timeline");
        for rr in &report.per_rank {
            let cap = rr.trace.as_ref().expect("traced run captures every rank");
            assert_eq!(cap.rank(), rr.rank);
            assert_eq!(cap.recorder.dropped(), 0, "no spans dropped on a tiny run");
            assert_eq!(cap.recorder.open_depth(), 0, "all spans closed");
            assert!(!cap.recorder.spans().is_empty());

            let attr = cap.attribution(&power);
            let exact = rr.ledger.energy_j(&power);
            assert!(
                attr.reconciles(exact, 1e-9),
                "{} rank {}: attribution {} J vs ledger {} J",
                mode.name(),
                rr.rank,
                attr.total_j(),
                exact
            );
            // Compute time is covered by exec spans, charged at busy draw.
            let exec = attr.by_category.get("exec").expect("exec spans present");
            assert!(exec.busy_s > 0.0 && exec.energy_j > 0.0);
        }
    }
}

#[test]
fn exported_trace_is_valid_and_survives_a_round_trip() {
    let mut cfg = preset("quickstart", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 3;
    cfg.train.target_loss = None;
    let server = ExecServer::for_run(&cfg).unwrap();
    let opts = TrainOptions { trace: true, ..Default::default() };
    let report = train_with(&cfg, &server, opts).unwrap();

    let tracks: Vec<Track> = report
        .per_rank
        .iter()
        .map(|rr| Track {
            name: format!("rank {}", rr.rank),
            tid: rr.rank as i64,
            recorder: &rr.trace.as_ref().unwrap().recorder,
        })
        .collect();
    let doc = chrome_trace(&tracks);
    validate_trace(&doc).expect("valid trace-event JSON");
    // Survives serialize -> parse (what Perfetto actually ingests).
    let back = Json::parse(&doc.pretty()).expect("trace re-parses");
    validate_trace(&back).expect("still valid after a round trip");
}

#[test]
fn traced_serve_reconciles_and_feeds_live_metrics() {
    let cfg = preset("quickstart", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let power = cfg.hardware.power;
    let scfg = ServeConfig {
        queue_depth: 16,
        max_batch: 8,
        linger_s: 1e-3,
        mode: Parallelism::Phantom,
    };
    let opts = PoolOptions { trace: true, ..Default::default() };
    let mut server = Server::start_with(&cfg, scfg, &exec, opts).unwrap();

    let n = cfg.model.n;
    let queries = 24usize;
    let mut rng = Prng::new(0x0B5);
    let mut t = 0.0f64;
    for _ in 0..queries {
        t += 5e-4;
        let x = Tensor::randn(&[n], 1.0, &mut rng);
        let (_, effective_s) = server.submit_blocking(t, x).unwrap();
        t = t.max(effective_s);
    }
    server.drain().unwrap();

    let snap = server.metrics();
    assert_eq!(snap.get("admitted"), Some(queries as f64));
    assert!(snap.get("batches").unwrap_or(0.0) >= 1.0);
    assert!(snap.get("latency_s_p50").unwrap_or(0.0) > 0.0);
    assert!(snap.get("j_per_query_ewma").unwrap_or(0.0) > 0.0);

    let events = server.take_host_events().expect("traced server records a timeline");
    assert!(
        events.events().iter().any(|e| e.cat == "serve.admit"),
        "admissions show up as instants"
    );
    assert!(
        events.events().iter().any(|e| e.cat == "serve.batch"),
        "dispatches show up as instants"
    );

    let (responses, stats, per_rank) = server.finish().unwrap();
    assert_eq!(responses.len(), queries);
    assert!(stats.batches >= 1);
    for pr in &per_rank {
        let cap = pr.trace.as_ref().expect("traced pool captures every rank");
        let attr = cap.attribution(&power);
        let exact = pr.ledger.energy_j(&power);
        assert!(
            attr.reconciles(exact, 1e-9),
            "rank {}: attribution {} J vs ledger {} J",
            pr.rank,
            attr.total_j(),
            exact
        );
    }
}
