//! Invariant tests for the energy ledger (ISSUE 2 hardening pass; Tripp et
//! al. motivate validating energy bookkeeping with invariants rather than
//! trusting it):
//!
//! * per-activity joules are non-negative for arbitrary ledgers,
//! * the activity buckets partition virtual time (busy + comm + idle ==
//!   now) and their energies sum to the reported total, also under any
//!   [t0, t1) windowing,
//! * on the quickstart preset, PP's communicate-energy never exceeds TP's
//!   (the Table II traffic claim, measured end-to-end through training).

use phantom::config::{preset, Parallelism};
use phantom::coordinator;
use phantom::energy::{Activity, EnergyLedger, PowerModel};
use phantom::runtime::ExecServer;
use phantom::util::proptest::{check, PropConfig};

fn random_ledger(rng: &mut phantom::util::prng::Prng) -> EnergyLedger {
    let mut led = EnergyLedger::new();
    let steps = rng.int_in(1, 40);
    for _ in 0..steps {
        let dur = rng.next_f64() * 2.0;
        match rng.int_in(0, 3) {
            0 => led.advance(dur, Activity::Compute),
            1 => led.advance(dur, Activity::Communicate),
            2 => led.advance(dur, Activity::Idle),
            _ => led.sync_to(led.now_s + dur * rng.next_f64()),
        }
    }
    led
}

#[test]
fn activity_buckets_partition_time_and_energy() {
    let cfg = PropConfig { cases: 128, ..PropConfig::default() };
    check("ledger bucket partition", cfg, |rng| {
        let led = random_ledger(rng);
        let model = PowerModel::frontier();

        let (busy, comm, idle) = (led.busy_s(), led.comm_s(), led.idle_s());
        if busy < 0.0 || comm < 0.0 || idle < 0.0 {
            return Err(format!("negative bucket: busy={busy} comm={comm} idle={idle}"));
        }
        let total_s = busy + comm + idle;
        if (total_s - led.now_s).abs() > 1e-9 * led.now_s.max(1.0) {
            return Err(format!("buckets {total_s} != clock {}", led.now_s));
        }

        // Per-activity joules are non-negative and sum to the total.
        let busy_j = model.busy_w * busy;
        let comm_j = model.idle_w * comm;
        let idle_j = model.idle_w * idle;
        if busy_j < 0.0 || comm_j < 0.0 || idle_j < 0.0 {
            return Err("negative per-activity energy".into());
        }
        let exact = led.energy_j(&model);
        let summed = busy_j + comm_j + idle_j;
        if (summed - exact).abs() > 1e-9 * exact.max(1.0) {
            return Err(format!("bucket energies {summed} != energy_j {exact}"));
        }

        // Windowing partitions the total at any cut point.
        let cut = led.now_s * rng.next_f64();
        let left = led.energy_j_between(&model, 0.0, cut);
        let right = led.energy_j_between(&model, cut, led.now_s);
        if left < 0.0 || right < 0.0 {
            return Err("negative windowed energy".into());
        }
        if (left + right - exact).abs() > 1e-9 * exact.max(1.0) {
            return Err(format!("window split {left}+{right} != {exact}"));
        }

        // The summary must agree with the ledger it summarizes.
        let s = led.summary();
        if (s.energy_j(&model) - exact).abs() > 1e-9 * exact.max(1.0) {
            return Err("summary energy diverges from ledger energy".into());
        }
        Ok(())
    });
}

#[test]
fn quickstart_pp_communicate_energy_at_most_tp() {
    let server = ExecServer::native();
    let model = PowerModel::frontier();
    let mut comm_energy = Vec::new();
    let mut totals = Vec::new();
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let mut cfg = preset("quickstart", mode).unwrap();
        cfg.train.max_iters = 6;
        let report = coordinator::train(&cfg, &server).unwrap();
        let comm_s: f64 = report.per_rank.iter().map(|r| r.ledger.comm_s).sum();
        // Communication is charged at the static draw B (the paper folds
        // it into the idle coefficient).
        comm_energy.push(model.idle_w * comm_s);
        totals.push(report.energy_total_j);
        for r in &report.per_rank {
            let bucket_sum = r.ledger.busy_s + r.ledger.comm_s + r.ledger.idle_s;
            assert!(
                (bucket_sum - r.ledger.end_s).abs() <= 1e-9 * r.ledger.end_s.max(1.0),
                "rank {}: buckets {} != clock {}",
                r.rank,
                bucket_sum,
                r.ledger.end_s
            );
            assert!(r.ledger.busy_s >= 0.0 && r.ledger.comm_s > 0.0 && r.ledger.idle_s >= 0.0);
        }
    }
    let (pp, tp) = (comm_energy[0], comm_energy[1]);
    assert!(pp <= tp, "PP communicate-energy {pp} J must be <= TP's {tp} J (Table II)");
    assert!(totals.iter().all(|&e| e > 0.0));
}
