//! Chaos integration suite (DESIGN.md §9): scripted failures over the real
//! training and serving stacks.
//!
//! * crash a training rank mid-run: the driver surfaces a structured error
//!   (rank id + injected-fault payload) instead of hanging, and `--resume`
//!   from the surviving snapshot reproduces the uninterrupted loss
//!   trajectory bit for bit — both parallelism modes;
//! * drop a message: the dropping rank errors, its peers surface the
//!   rendezvous timeout promptly (injectable timeout, no 60 s hang);
//! * poison storm: a poisoned fabric fails every rank loudly;
//! * crash a serve-pool rank: the batch errors, shutdown names the dead
//!   rank, and a rebuilt pool hot-swapped onto the snapshot replays the
//!   failed batch — zero dropped, zero reordered, bitwise-equal answers.

use std::time::{Duration, Instant};

use phantom::comm::{FaultAction, Fabric};
use phantom::config::{preset, Parallelism, ServeConfig};
use phantom::coordinator::{train_with, TrainOptions};
use phantom::runtime::ExecServer;
use phantom::simnet::NetworkProfile;
use phantom::tensor::Tensor;
use phantom::testkit::{
    collectives_per_forward, serve_crash_swap, train_crash_resume, FaultPlan,
};

fn tdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("phantom-chaos-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn crash_resume_is_bit_identical_both_modes() {
    for (mode, crash_rank, crash_iter) in
        [(Parallelism::Phantom, 1usize, 3u64), (Parallelism::Tensor, 0, 4)]
    {
        let cfg = preset("tiny_p2", mode).unwrap();
        let dir = tdir(&format!("resume-{}", mode.name()));
        let report = train_crash_resume(&cfg, 8, 2, crash_rank, crash_iter, &dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(
            report.bit_identical,
            "{}: resumed {:?} vs baseline {:?}",
            mode.name(),
            report.resumed,
            report.baseline
        );
        assert_eq!(report.baseline.len(), 8, "{}", mode.name());
        // The crash surfaced structurally: who died, and why.
        let msg = &report.crash_error;
        assert!(msg.contains(&format!("rank {crash_rank} panicked")), "{}", msg);
        assert!(msg.contains("injected fault"), "{msg}");
        assert!(msg.contains("crashed"), "{msg}");
    }
}

#[test]
fn injected_drop_surfaces_timeout_promptly_not_hang() {
    // Rank 0 drops its third collective; rank 1 must surface the rendezvous
    // timeout through the driver in well under the production 60 s.
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 4;
    let server = ExecServer::for_run(&cfg).unwrap();
    let t0 = Instant::now();
    let err = train_with(
        &cfg,
        &server,
        TrainOptions {
            faults: Some(FaultPlan::drop_message(0, 2).injector_factory()),
            rendezvous_timeout: Some(Duration::from_millis(250)),
            ..Default::default()
        },
    )
    .expect_err("a dropped message must fail the run");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("dropped") || msg.contains("timeout"),
        "error should name the drop or the timeout: {msg}"
    );
    assert!(t0.elapsed() < Duration::from_secs(20), "drop must not ride the 60 s timeout");
}

#[test]
fn poison_storm_fails_every_rank_loudly() {
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 4;
    let server = ExecServer::for_run(&cfg).unwrap();
    let plan = FaultPlan::new().with(1, 5, FaultAction::Poison);
    let err = train_with(
        &cfg,
        &server,
        TrainOptions { faults: Some(plan.injector_factory()), ..Default::default() },
    )
    .expect_err("a poisoned fabric must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("poisoned"), "{msg}");
    let fired = plan.fired();
    assert_eq!(fired.len(), 1);
    assert_eq!((fired[0].rank, fired[0].seq), (1, 5));
}

#[test]
fn serve_crash_hot_swap_recovers_with_zero_drops() {
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let cfg = preset("tiny_p2", mode).unwrap();
        let scfg = ServeConfig {
            max_batch: cfg.train.batch,
            queue_depth: 4 * cfg.train.batch,
            linger_s: 1e-3,
            mode,
        };
        // Crash rank 1 inside batch 2 (layers collectives per batch).
        let crash_seq = collectives_per_forward(cfg.model.layers) * 2 + 1;
        let report = serve_crash_swap(&cfg, &scfg, 5, 1, crash_seq).unwrap();
        assert_eq!(report.recovered_batch, 2, "{}", mode.name());
        // outputs_match doubles as the zero-dropped proof (a missing answer
        // matches nothing); per-batch ordering is enforced inside
        // RankPool::execute, which rejects out-of-sequence completions.
        assert!(report.outputs_match, "{}: answers diverged after hot-swap", mode.name());
        assert!(
            report.swap_observable,
            "{}: swap weights indistinguishable — the hot swap was not exercised",
            mode.name()
        );
        assert!(
            report.shutdown_error.contains("serve rank 1 panicked"),
            "{}: {}",
            mode.name(),
            report.shutdown_error
        );
    }
}

#[test]
fn run_ranks_failure_shape_carries_rank_and_context() {
    // The structured-panic contract chaos tests build on: an injected
    // crash inside a collective propagates rank id + payload + collective
    // context through Fabric::run_ranks.
    let plan = FaultPlan::crash(2, 1);
    let factory = plan.injector_factory();
    let err = Fabric::run_ranks(
        3,
        NetworkProfile::frontier(),
        Duration::from_secs(60),
        move |mut ep, mut led| {
            if let Some(inj) = factory.for_rank(ep.rank) {
                ep.arm_faults(inj);
            }
            for _ in 0..2 {
                if ep.all_reduce(Tensor::filled(&[2], 1.0), &mut led).is_err() {
                    break;
                }
            }
            ep.rank
        },
    )
    .expect_err("rank 2 crashed");
    assert_eq!(err.rank, 2);
    assert!(err.payload.contains("injected fault: rank 2 crashed"), "{}", err.payload);
    assert!(err.payload.contains("'all_reduce'"), "{}", err.payload);
    assert!(err.payload.contains("collective #1"), "{}", err.payload);
    assert_eq!(err.all, vec![(2, err.payload.clone())]);
}
