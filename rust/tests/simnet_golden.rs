//! Golden regression for the collective-model fit (paper Appendix, Eqn. 26
//! / Table III): refitting the model on timings synthesized from the
//! Table III constants must recover those constants, for all four
//! collectives — exactly under zero noise, within tolerance under the
//! multiplicative log-normal noise the fitting pipeline assumes.

use phantom::simnet::{fit, synthesize_observations, Collective, NetworkProfile};
use phantom::util::prng::Prng;

#[test]
fn refit_recovers_table3_constants_for_all_collectives() {
    let profile = NetworkProfile::frontier();
    for (i, collective) in Collective::ALL.iter().enumerate() {
        let truth = *profile.model(*collective);

        // Noiseless synthesis: the fit must be numerically exact.
        let mut rng = Prng::new(0x7AB3 + i as u64);
        let obs = synthesize_observations(&truth, 0.0, &mut rng);
        let exact = fit(&obs).unwrap_or_else(|| panic!("{}: fit failed", collective.name()));
        assert!(
            (exact.model.c1 - truth.c1).abs() < 1e-6,
            "{}: c1 {} vs {}",
            collective.name(),
            exact.model.c1,
            truth.c1
        );
        assert!(
            (exact.model.c2 - truth.c2).abs() < 1e-9,
            "{}: c2 {} vs {}",
            collective.name(),
            exact.model.c2,
            truth.c2
        );
        assert!(
            exact.model.c3.abs() < 1e-4,
            "{}: c3 {} should vanish (Table III reports ~0)",
            collective.name(),
            exact.model.c3
        );
        assert!(exact.rmse_log2_us < 1e-6);

        // Noisy synthesis (sigma = 0.1 in log space, the paper-style
        // multiplicative measurement noise): constants within tolerance.
        let obs = synthesize_observations(&truth, 0.1, &mut rng);
        let noisy = fit(&obs).unwrap();
        let c1_rel = (noisy.model.c1 - truth.c1).abs() / truth.c1;
        let c2_rel = (noisy.model.c2 - truth.c2).abs() / truth.c2;
        assert!(
            c1_rel < 0.10,
            "{}: latency term off by {:.1}% ({} vs {})",
            collective.name(),
            c1_rel * 100.0,
            noisy.model.c1,
            truth.c1
        );
        assert!(
            c2_rel < 0.10,
            "{}: bandwidth term off by {:.1}% ({} vs {})",
            collective.name(),
            c2_rel * 100.0,
            noisy.model.c2,
            truth.c2
        );
        assert!(
            noisy.model.c3.abs() < 5.0,
            "{}: c3 {} drifted far from Table III's ~0 us",
            collective.name(),
            noisy.model.c3
        );
        assert!(
            noisy.rmse_log2_us > 0.0 && noisy.rmse_log2_us < 0.25,
            "{}: rmse_log2_us {} out of range for sigma=0.1",
            collective.name(),
            noisy.rmse_log2_us
        );

        // The recovered model must predict like the truth across the
        // paper's sweep grid (2^2..2^26 floats, p in 2..256).
        for &(m, p) in &[(16usize, 4usize), (1 << 12, 16), (1 << 20, 64), (1 << 26, 256)] {
            let want = truth.time(m, p);
            let got = noisy.model.time(m, p);
            let rel = (got - want).abs() / want.max(1e-12);
            assert!(
                rel < 0.20,
                "{} at m={m} p={p}: predicted {got} vs truth {want} ({:.1}% off)",
                collective.name(),
                rel * 100.0
            );
        }
    }
}
