//! Property tests for the hybrid data layout (ISSUE 5): shard boundaries
//! key on (dp_replica, model_rank) only, and re-assembling every DP
//! replica's row range × every model rank's column shard reproduces
//! `Teacher::batch` bitwise — including when `batch % dp != 0`.

use phantom::data::{dp_row_range, BatchCache, Teacher};
use phantom::tensor::Tensor;
use phantom::util::proptest::{check, PropConfig};

/// Row-concatenate [B_d, n] tensors into one [B, n] tensor.
fn row_concat(rows: &[Tensor]) -> Tensor {
    let n = rows[0].shape()[1];
    let mut data = Vec::new();
    let mut b = 0;
    for r in rows {
        assert_eq!(r.shape()[1], n);
        b += r.shape()[0];
        data.extend_from_slice(r.data());
    }
    Tensor::from_vec(&[b, n], data).unwrap()
}

#[test]
fn hybrid_shards_reassemble_the_batch_bitwise_for_any_remainder() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("hybrid shard reassembly", cfg, |rng| {
        let p = rng.int_in(1, 4) as usize;
        let n = p * rng.int_in(2, 6) as usize;
        let dp = rng.int_in(1, 4) as usize;
        // batch >= dp, deliberately often NOT divisible by dp.
        let batch = dp + rng.int_in(0, 7) as usize;
        let seed = rng.next_u64();
        let iter = rng.int_in(0, 5);

        let teacher = Teacher::new(n, seed);
        let (x, y) = teacher.batch(batch, iter).map_err(|e| e.to_string())?;

        // Row ranges partition the batch contiguously and in order.
        let mut covered = 0usize;
        for d in 0..dp {
            let (start, len) = dp_row_range(batch, dp, d);
            if start != covered {
                return Err(format!(
                    "batch={batch} dp={dp} d={d}: range starts at {start}, want {covered}"
                ));
            }
            covered += len;
        }
        if covered != batch {
            return Err(format!("batch={batch} dp={dp}: ranges cover {covered} rows"));
        }

        // Reassemble: for each replica, column shards glue back into the
        // replica's rows; replica rows glue back into the full batch.
        let mut x_rows = Vec::with_capacity(dp);
        let mut y_rows = Vec::with_capacity(dp);
        for d in 0..dp {
            let mut xs = Vec::with_capacity(p);
            let mut ys = Vec::with_capacity(p);
            for r in 0..p {
                let (xr, yr) = teacher
                    .hybrid_shard(batch, iter, r, p, d, dp)
                    .map_err(|e| e.to_string())?;
                // Model-group peers see the same rows: shard shape is the
                // replica's row count x n/p.
                let (_, want_len) = dp_row_range(batch, dp, d);
                if xr.shape() != &[want_len, n / p] {
                    return Err(format!(
                        "d={d} r={r}: shard shaped {:?}, want [{want_len}, {}]",
                        xr.shape(),
                        n / p
                    ));
                }
                xs.push(xr);
                ys.push(yr);
            }
            x_rows.push(Tensor::from_col_shards(&xs).map_err(|e| e.to_string())?);
            y_rows.push(Tensor::from_col_shards(&ys).map_err(|e| e.to_string())?);
        }
        let x_back = row_concat(&x_rows);
        let y_back = row_concat(&y_rows);
        for (i, (a, b)) in x_back.data().iter().zip(x.data()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("x[{i}]: {a} != {b} (bitwise contract)"));
            }
        }
        for (i, (a, b)) in y_back.data().iter().zip(y.data()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("y[{i}]: {a} != {b} (bitwise contract)"));
            }
        }

        // The shared BatchCache serves the identical hybrid shards.
        let cache = BatchCache::new(teacher.clone(), batch, p, dp, 8);
        for d in 0..dp {
            for r in 0..p {
                let (xc, yc) = cache.shard(iter, d * p + r).map_err(|e| e.to_string())?;
                let (xd, yd) = teacher
                    .hybrid_shard(batch, iter % 8, r, p, d, dp)
                    .map_err(|e| e.to_string())?;
                if xc != xd || yc != yd {
                    return Err(format!("cache diverges from direct shard at d={d} r={r}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn pure_batch_shard_is_the_dp1_special_case() {
    // `batch_shard` must stay exactly `hybrid_shard(.., dp_rank=0, dp=1)`:
    // the pre-hybrid data path is the dp=1 slice of the hybrid one.
    let teacher = Teacher::new(12, 77);
    for rank in 0..3 {
        let (xa, ya) = teacher.batch_shard(5, 2, rank, 3).unwrap();
        let (xb, yb) = teacher.hybrid_shard(5, 2, rank, 3, 0, 1).unwrap();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }
}
