//! Kernel regression gate, run under tier-1 and as a named CI step.
//!
//! For every tracked GEMM shape this measures the naive oracle, the frozen
//! seed kernel (tensor::seed) and the live tuned engine (tensor::gemm) in
//! one process, writes the full trajectory to BENCH_kernels.json at the
//! repo root, and then enforces `ci/kernel_baseline.json`:
//!
//! * per-shape `min_speedup_vs_naive` floors — speedup-vs-naive is a
//!   machine-independent yardstick (both sides run on the same box), so the
//!   committed baseline transfers across CI hardware;
//! * `min_geomean_speedup_vs_seed` — the ≥1.5× tentpole claim, asserted
//!   when the AVX2 kernels are active (the portable fallback also beats the
//!   seed, but the margin is ISA-dependent, so floors are halved there).
//!
//! The baseline's `tolerance` (0.85 = the ">15% regression fails" rule)
//! absorbs CI load jitter; the recorded numbers are the real ones. To re-pin
//! after an intentional kernel change: run this test, read the recorded
//! speedups from BENCH_kernels.json, and commit conservative floors (see
//! DESIGN.md §11).

use std::path::PathBuf;
use std::time::Instant;

use phantom::tensor::seed::gemm_acc_seed;
use phantom::tensor::simd::{self, Isa};
use phantom::tensor::tune::{self, TRACKED_SHAPES};
use phantom::tensor::{gemm_acc, Tensor};
use phantom::util::json::{read_json, write_records_json_with_meta};
use phantom::util::prng::Prng;
use phantom::util::proptest::assert_close;

/// Minimum wall time of `runs` executions (min is the stablest estimator
/// under background load).
fn best_of<F: FnMut()>(runs: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
}

#[test]
fn tracked_shapes_meet_committed_baseline() {
    let isa = simd::active();
    let avx2 = isa == Isa::Avx2Fma;
    let mut records: Vec<(String, f64)> = Vec::new();
    let mut speedups_vs_naive: Vec<(String, f64)> = Vec::new();
    let mut geomean_seed_log = 0.0f64;
    let mut geomean_naive_log = 0.0f64;

    let mut rng = Prng::new(0x6A7E);
    for &(m, k, n) in TRACKED_SHAPES {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);

        // Correctness before speed: tuned and seed must match the oracle.
        let want = a.matmul_naive(&b).unwrap();
        let mut tuned_out = vec![0.0f32; m * n];
        gemm_acc(a.data(), m, k, b.data(), n, &mut tuned_out);
        assert_close(&tuned_out, want.data(), 1e-3, 1e-4)
            .unwrap_or_else(|e| panic!("tuned != naive at {m}x{k}x{n}: {e}"));
        let mut seed_out = vec![0.0f32; m * n];
        gemm_acc_seed(a.data(), m, k, b.data(), n, &mut seed_out);
        assert_close(&seed_out, want.data(), 1e-3, 1e-4)
            .unwrap_or_else(|e| panic!("seed != naive at {m}x{k}x{n}: {e}"));

        let big = m * k * n >= 1 << 26;
        let naive_runs = if big { 2 } else { 3 };
        let fast_runs = if big { 4 } else { 8 };
        let t_naive = best_of(naive_runs, || {
            let _ = a.matmul_naive(&b).unwrap();
        });
        let t_seed = best_of(fast_runs, || {
            seed_out.fill(0.0);
            gemm_acc_seed(a.data(), m, k, b.data(), n, &mut seed_out);
        });
        let t_tuned = best_of(fast_runs, || {
            tuned_out.fill(0.0);
            gemm_acc(a.data(), m, k, b.data(), n, &mut tuned_out);
        });

        let shape = format!("{m}x{k}x{n}");
        let vs_naive = t_naive / t_tuned;
        let vs_seed = t_seed / t_tuned;
        eprintln!(
            "{shape}: naive {:.3}ms, seed {:.3}ms, tuned {:.3}ms — {vs_naive:.2}x vs naive, \
             {vs_seed:.2}x vs seed",
            t_naive * 1e3,
            t_seed * 1e3,
            t_tuned * 1e3
        );
        records.push((format!("gemm_naive_{shape}_ns"), t_naive * 1e9));
        records.push((format!("gemm_seed_{shape}_ns"), t_seed * 1e9));
        records.push((format!("gemm_{shape}_ns"), t_tuned * 1e9));
        records.push((format!("speedup_vs_naive_{shape}"), vs_naive));
        records.push((format!("speedup_vs_seed_{shape}"), vs_seed));
        speedups_vs_naive.push((shape, vs_naive));
        geomean_seed_log += vs_seed.ln();
        geomean_naive_log += vs_naive.ln();
    }

    let geomean_seed = (geomean_seed_log / TRACKED_SHAPES.len() as f64).exp();
    let geomean_naive = (geomean_naive_log / TRACKED_SHAPES.len() as f64).exp();
    eprintln!("geomean speedup: {geomean_seed:.2}x vs seed, {geomean_naive:.2}x vs naive");
    records.push(("geomean_speedup_vs_seed".to_string(), geomean_seed));
    records.push(("geomean_speedup_vs_naive".to_string(), geomean_naive));
    records.push(("isa_avx2".to_string(), if avx2 { 1.0 } else { 0.0 }));
    records.push(("tuned_classes".to_string(), tune::installed_classes() as f64));

    // Record the trajectory before asserting, so a gate failure still
    // uploads the numbers that explain it.
    let bench_path = repo_root().join("BENCH_kernels.json");
    let meta = phantom::util::json::BenchMeta::new("kernels", 0.0);
    write_records_json_with_meta(&bench_path, &records, &meta)
        .expect("write BENCH_kernels.json");

    // -- the committed gate ------------------------------------------------
    let baseline_path = repo_root().join("ci/kernel_baseline.json");
    let baseline = read_json(&baseline_path)
        .unwrap_or_else(|e| panic!("read {}: {e}", baseline_path.display()));
    assert_eq!(baseline.get("version").as_i64(), Some(1), "unknown baseline version");
    let tolerance = baseline.get("tolerance").as_f64().unwrap_or(0.85);
    // The portable fallback is slower than the AVX2 kernels; halve the
    // floors there so the gate still means something on exotic runners.
    let isa_scale = if avx2 { 1.0 } else { 0.5 };

    let shapes = baseline.get("shapes").as_obj().expect("baseline shapes{}");
    for (shape, entry) in shapes {
        let floor = entry.get("min_speedup_vs_naive").as_f64().unwrap_or_else(|| {
            panic!("baseline shape {shape} missing min_speedup_vs_naive")
        });
        let measured = speedups_vs_naive
            .iter()
            .find(|(s, _)| s == shape)
            .unwrap_or_else(|| panic!("baseline shape {shape} is not in TRACKED_SHAPES"))
            .1;
        let min = floor * tolerance * isa_scale;
        assert!(
            measured >= min,
            "kernel regression at {shape}: {measured:.2}x vs naive, gate {min:.2}x \
             (baseline {floor:.2}x, tolerance {tolerance}, isa_scale {isa_scale}); \
             see BENCH_kernels.json"
        );
    }

    if avx2 {
        let min_geo = baseline.get("min_geomean_speedup_vs_seed").as_f64().unwrap_or(1.5);
        let min = min_geo * tolerance;
        assert!(
            geomean_seed >= min,
            "tuned kernels only {geomean_seed:.2}x geomean over the seed kernel \
             (gate {min:.2}x from baseline {min_geo:.2}x); see BENCH_kernels.json"
        );
    } else {
        eprintln!("portable ISA: geomean-vs-seed gate skipped (recorded {geomean_seed:.2}x)");
    }
}
