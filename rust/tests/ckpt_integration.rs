//! End-to-end checkpoint subsystem integration (DESIGN.md §8):
//!
//! * crash-resume: train 10 iters -> snapshot -> resume 10 more produces
//!   the uninterrupted 20-iter run's loss trajectory bit for bit (PP+Adam
//!   and TP+Momentum),
//! * re-sharding: a TP snapshot re-sharded to PP (and an elastic PP merge
//!   chain) is forward-equivalent to its source, proven both host-side and
//!   through the real sharded serving pipeline,
//! * hot swap: a running serve pool adopts a re-sharded snapshot between
//!   batches without dropping or reordering any queued query,
//! * perf trajectory: save/load/reshard throughput recorded to
//!   BENCH_ckpt.json (and read back with util::json::read_records_json).

use std::path::PathBuf;

use phantom::ckpt::{reshard, Snapshot};
use phantom::config::{preset, CkptPolicy, ModelConfig, OptimizerConfig, Parallelism, ServeConfig};
use phantom::coordinator::{train_with, TrainOptions};
use phantom::runtime::ExecServer;
use phantom::serve::Server;
use phantom::tensor::Tensor;
use phantom::util::json::{read_records_json, write_records_json_with_meta, BenchMeta};
use phantom::util::prng::Prng;
use phantom::util::proptest::assert_close;

fn topts(ckpt: Option<CkptPolicy>, resume: Option<Snapshot>) -> TrainOptions {
    TrainOptions { ckpt, resume, ..Default::default() }
}

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("phantom-ckpt-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn resume_case(mode: Parallelism, opt: OptimizerConfig, tag: &str) {
    let root = tdir(tag);
    let mut cfg = preset("tiny_p2", mode).unwrap();
    cfg.train.optimizer = opt;

    // Uninterrupted reference: 20 iterations.
    let mut full_cfg = cfg.clone();
    full_cfg.train.max_iters = 20;
    let server = ExecServer::for_run(&full_cfg).unwrap();
    let full = train_with(&full_cfg, &server, TrainOptions::default()).unwrap();
    assert_eq!(full.iterations, 20);

    // First leg: 10 iterations with periodic snapshots every 5.
    let mut leg_cfg = cfg.clone();
    leg_cfg.train.max_iters = 10;
    let policy = CkptPolicy { every: 5, dir: root.clone() };
    let leg =
        train_with(&leg_cfg, &server, topts(Some(policy), None)).unwrap();
    assert_eq!(leg.iterations, 10);
    assert!(root.join("ckpt-000005").join("manifest.json").exists());
    assert!(root.join("ckpt-000010").join("manifest.json").exists());

    // The first leg must itself match the reference prefix bitwise.
    assert_eq!(&full.losses[..10], &leg.losses[..], "{tag}: first leg diverged");

    // "Crash", then resume from the iteration-10 snapshot to 20 total.
    let snap = Snapshot::load(&root.join("ckpt-000010")).unwrap();
    assert_eq!(snap.progress.iter, 10);
    let mut resume_cfg = snap.config.clone();
    resume_cfg.train.max_iters = 20;
    let resumed =
        train_with(&resume_cfg, &server, topts(None, Some(snap))).unwrap();

    // Bit-identical continuation: the resumed run's full trajectory equals
    // the uninterrupted one, f64-exactly.
    assert_eq!(resumed.iterations, 20, "{tag}");
    assert_eq!(resumed.losses, full.losses, "{tag}: resumed trajectory diverged");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn resume_is_bit_identical_pp_adam() {
    resume_case(
        Parallelism::Phantom,
        OptimizerConfig::Adam { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        "pp-adam",
    );
}

#[test]
fn resume_is_bit_identical_tp_momentum() {
    resume_case(Parallelism::Tensor, OptimizerConfig::Momentum { lr: 0.5, beta: 0.9 }, "tp-mom");
}

#[test]
fn resume_from_satisfied_snapshot_trains_nothing() {
    let root = tdir("satisfied");
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 6;
    let server = ExecServer::for_run(&cfg).unwrap();
    let policy = CkptPolicy { every: 3, dir: root.clone() };
    train_with(&cfg, &server, topts(Some(policy), None)).unwrap();

    // Resuming with the same cap: the snapshot already satisfies it.
    let snap = Snapshot::load(&root.join("ckpt-000006")).unwrap();
    let report =
        train_with(&cfg, &server, topts(None, Some(snap))).unwrap();
    assert_eq!(report.iterations, 6);
    assert!(report.per_rank.is_empty(), "no rank work for a satisfied snapshot");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn resume_rejects_mismatched_config() {
    let root = tdir("mismatch");
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 4;
    let server = ExecServer::for_run(&cfg).unwrap();
    let policy = CkptPolicy { every: 4, dir: root.clone() };
    train_with(&cfg, &server, topts(Some(policy), None)).unwrap();
    let snap = Snapshot::load(&root.join("ckpt-000004")).unwrap();

    let mut wrong_seed = cfg.clone();
    wrong_seed.train.seed ^= 1;
    wrong_seed.train.max_iters = 8;
    let opts = topts(None, Some(snap.clone()));
    let err = train_with(&wrong_seed, &server, opts);
    assert!(err.is_err(), "a different data seed must refuse to resume");

    let mut wrong_opt = cfg.clone();
    wrong_opt.train.optimizer = OptimizerConfig::Sgd { lr: 0.9 };
    wrong_opt.train.max_iters = 8;
    let err = train_with(&wrong_opt, &server, topts(None, Some(snap)));
    assert!(err.is_err(), "a different optimizer must refuse to resume");
    std::fs::remove_dir_all(&root).ok();
}

/// A trained TP p=8 snapshot re-sharded to PP p=2 runs through the REAL
/// sharded forward pipeline (serve pool) and matches the TP source's
/// host-side forward — the acceptance-criteria scenario end-to-end,
/// including the disk round-trip of the dense-phantom layout.
#[test]
fn trained_tp_snapshot_reshards_to_pp_and_serves() {
    let root = tdir("reshard-serve");
    let mut tp_cfg = preset("tiny_p2", Parallelism::Tensor).unwrap();
    tp_cfg.p = 8;
    // k is unused by TP; it must only satisfy k < n/p for config validation.
    tp_cfg.model = ModelConfig { n: 32, layers: 2, k: 2 };
    tp_cfg.artifact = Some("ckpt_tp8".to_string());
    tp_cfg.train.max_iters = 6;
    let server = ExecServer::for_run(&tp_cfg).unwrap();
    let policy = CkptPolicy { every: 6, dir: root.clone() };
    train_with(&tp_cfg, &server, topts(Some(policy), None)).unwrap();

    let tp_snap = Snapshot::load(&root.join("ckpt-000006")).unwrap();
    let pp_snap = reshard(&tp_snap, 2, Parallelism::Phantom).unwrap();
    assert_eq!(pp_snap.k(), 16, "dense-phantom conversion: k = n/p");
    // disk round-trip of the re-sharded layout
    let pp_dir = root.join("resharded-pp2");
    pp_snap.save(&pp_dir).unwrap();
    let pp_snap = Snapshot::load(&pp_dir).unwrap();
    assert_eq!(pp_snap.progress.iter, tp_snap.progress.iter, "progress survives reshard");

    // host-side equivalence
    let mut rng = Prng::new(0x7E57);
    let x = Tensor::randn(&[6, 32], 1.0, &mut rng);
    let want = tp_snap.forward_host(&x).unwrap();
    let got = pp_snap.forward_host(&x).unwrap();
    assert_close(got.data(), want.data(), 1e-4, 1e-5).unwrap();

    // through the real sharded pipeline: a p=2 PP pool hot-swapped onto
    // the re-sharded snapshot must reproduce the TP source's outputs.
    let mut pool_cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&pool_cfg).unwrap();
    pool_cfg.train.seed = 0xD1FF; // pool starts with unrelated weights
    let scfg = ServeConfig {
        queue_depth: 16,
        max_batch: 8,
        linger_s: 1e-3,
        mode: Parallelism::Phantom,
    };
    let mut server = Server::start(&pool_cfg, scfg, &exec).unwrap();
    server.hot_swap(&pp_snap).unwrap();
    for i in 0..6usize {
        let row = Tensor::from_vec(&[32], x.data()[i * 32..(i + 1) * 32].to_vec()).unwrap();
        server.submit_blocking(1e-3 * (i + 1) as f64, row).unwrap();
    }
    let (responses, stats, _) = server.finish().unwrap();
    assert_eq!(responses.len(), 6);
    assert_eq!(stats.rejected, 0);
    for (i, r) in responses.iter().enumerate() {
        let want_row = &want.data()[i * 32..(i + 1) * 32];
        assert_close(r.y.data(), want_row, 1e-4, 1e-5)
            .unwrap_or_else(|e| panic!("query {i} after swap: {e}"));
    }
    std::fs::remove_dir_all(&root).ok();
}

/// Elastic PP merge chain p=8 -> p=4 -> p=2 on an initialized model stays
/// forward-equivalent and keeps the compressed structure (k scales by the
/// merge factor instead of densifying).
#[test]
fn elastic_pp_merge_chain_is_equivalent() {
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.p = 8;
    cfg.model = ModelConfig { n: 64, layers: 2, k: 3 };
    cfg.artifact = Some("ckpt_pp8".to_string());
    let p8 = Snapshot::init(&cfg).unwrap();
    let p4 = reshard(&p8, 4, Parallelism::Phantom).unwrap();
    let p2 = reshard(&p4, 2, Parallelism::Phantom).unwrap();
    assert_eq!(p4.k(), 6);
    assert_eq!(p2.k(), 12);

    let mut rng = Prng::new(0xE1a5);
    let x = Tensor::randn(&[5, 64], 1.0, &mut rng);
    let want = p8.forward_host(&x).unwrap();
    for (snap, tag) in [(&p4, "p=4"), (&p2, "p=2")] {
        let got = snap.forward_host(&x).unwrap();
        assert_close(got.data(), want.data(), 1e-4, 1e-5)
            .unwrap_or_else(|e| panic!("{tag}: {e}"));
    }
}

/// Queries queued before a hot swap are served by the new weights — none
/// dropped, none reordered — while queries dispatched before the swap kept
/// the old weights.
#[test]
fn hot_swap_preserves_queued_queries() {
    let cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let n = cfg.model.n;
    let old_snap = Snapshot::init(&cfg).unwrap(); // == the pool's start weights

    // A different model to swap in: TP p=4 with another seed, re-sharded
    // down to this pool's p=2 phantom layout.
    let mut other = cfg.clone();
    other.mode = Parallelism::Tensor;
    other.p = 4;
    other.train.seed = 0x5EED5;
    other.artifact = Some("ckpt_swap_src".to_string());
    let new_snap = reshard(&Snapshot::init(&other).unwrap(), 2, Parallelism::Phantom).unwrap();

    let scfg = ServeConfig {
        queue_depth: 16,
        max_batch: 4,
        linger_s: 1e-3,
        mode: Parallelism::Phantom,
    };
    let mut server = Server::start(&cfg, scfg, &exec).unwrap();
    let mut rng = Prng::new(0xABCD);
    let rows: Vec<Tensor> = (0..8).map(|_| Tensor::randn(&[n], 1.0, &mut rng)).collect();

    // First 4 queries: the fill rule (max_batch = 4) dispatches them at the
    // 4th arrival, with the ORIGINAL weights.
    for (i, row) in rows[..4].iter().enumerate() {
        server.submit_blocking(1e-4 * (i + 1) as f64, row.clone()).unwrap();
    }
    // Next 3 arrive and stay queued (not enough for the fill rule, linger
    // deadline not yet passed by the frontier).
    for (i, row) in rows[4..7].iter().enumerate() {
        server.submit_blocking(1.0 + 1e-4 * (i + 1) as f64, row.clone()).unwrap();
    }
    assert_eq!(server.queued(), 3, "three queries must still be queued at the swap");
    server.hot_swap(&new_snap).unwrap();
    // One more query after the swap, then drain.
    server.submit_blocking(2.0, rows[7].clone()).unwrap();
    let (responses, stats, _) = server.finish().unwrap();

    assert_eq!(responses.len(), 8, "hot swap must not drop queued queries");
    assert_eq!(stats.rejected, 0);
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "responses must stay in admission order");
    }

    let x_all = {
        let mut flat = Vec::with_capacity(8 * n);
        for row in &rows {
            flat.extend_from_slice(row.data());
        }
        Tensor::from_vec(&[8, n], flat).unwrap()
    };
    let y_old = old_snap.forward_host(&x_all).unwrap();
    let y_new = new_snap.forward_host(&x_all).unwrap();
    for (i, r) in responses.iter().enumerate() {
        let (want, tag) = if i < 4 {
            (&y_old.data()[i * n..(i + 1) * n], "old")
        } else {
            (&y_new.data()[i * n..(i + 1) * n], "new")
        };
        assert_close(r.y.data(), want, 1e-4, 1e-5)
            .unwrap_or_else(|e| panic!("query {i} ({tag} weights): {e}"));
    }
    // The two models genuinely differ, so the swap was observable.
    let mut max_diff = 0.0f32;
    for (a, b) in y_old.data().iter().zip(y_new.data()) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff > 1e-3, "swap target must differ from the start weights");
}

/// Save/restore/reshard throughput -> BENCH_ckpt.json (CI artifact), read
/// back through util::json::read_records_json.
#[test]
fn ckpt_perf_trajectory_records() {
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.p = 4;
    cfg.model = ModelConfig { n: 128, layers: 2, k: 8 };
    cfg.artifact = Some("ckpt_bench".to_string());
    let snap = Snapshot::init(&cfg).unwrap();
    let root = tdir("bench");
    let dir = root.join("snap");

    let t0 = std::time::Instant::now();
    snap.save(&dir).unwrap();
    let save_s = t0.elapsed().as_secs_f64();

    let bytes: u64 = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.metadata().map(|m| m.len()).unwrap_or(0))
        .sum();

    let t0 = std::time::Instant::now();
    let loaded = Snapshot::load(&dir).unwrap();
    let load_s = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let merged = reshard(&loaded, 2, Parallelism::Phantom).unwrap();
    let reshard_s = t0.elapsed().as_secs_f64();
    assert_eq!(merged.p(), 2);

    let mb = bytes as f64 / 1e6;
    let records = vec![
        ("snapshot_mb".to_string(), mb),
        ("save_s".to_string(), save_s),
        ("load_s".to_string(), load_s),
        ("reshard_p4_to_p2_s".to_string(), reshard_s),
        ("save_mb_per_s".to_string(), mb / save_s.max(1e-9)),
        ("load_mb_per_s".to_string(), mb / load_s.max(1e-9)),
    ];
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_ckpt.json");
    write_records_json_with_meta(&path, &records, &BenchMeta::new("ckpt", 0.0)).unwrap();

    let back = read_records_json(&path).unwrap();
    for key in ["snapshot_mb", "save_s", "load_s", "reshard_p4_to_p2_s"] {
        let (_, v) = back
            .iter()
            .find(|(k, _)| k == key)
            .unwrap_or_else(|| panic!("missing record {key}"));
        assert!(*v > 0.0, "{key} must be positive, got {v}");
    }
    eprintln!(
        "ckpt trajectory: {mb:.2} MB, save {save_s:.4}s, load {load_s:.4}s -> {}",
        path.display()
    );
    std::fs::remove_dir_all(&root).ok();
}
