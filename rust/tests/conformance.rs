//! Differential conformance suite (DESIGN.md §9, acceptance criteria of
//! the testkit ISSUE):
//!
//! * the randomized sweep passes TP ≡ PP ≡ dense-oracle on >= 25 configs
//!   (distributed train vs single-rank `ReferenceTrainer` loss
//!   trajectories, fused kernels vs naive math gradients, TP <-> PP
//!   re-shard forward equivalence);
//! * the determinism contract: the same seeded `FaultPlan` reproduces a
//!   byte-identical fault schedule — generation-side (canonical bytes)
//!   and run-side (the fired log of two identical runs) — and injected
//!   delays perturb only virtual time, never the math;
//! * one crash-resume trajectory match rides the same contract (the full
//!   chaos scenarios live in tests/chaos_integration.rs).
//!
//! Also refreshes BENCH_conformance.json at the repo root, mirroring the
//! serve/ckpt bench records.

use phantom::config::{preset, Parallelism};
use phantom::coordinator::{train_with, TrainOptions};
use phantom::runtime::ExecServer;
use phantom::testkit::{
    run_sweep, train_crash_resume, FaultPlan, StormSpec, SweepConfig,
};
use phantom::util::json::read_records_json;

#[test]
fn differential_sweep_passes_25_randomized_configs() {
    // >= 25 randomized (n, p, TP|PP, backend, batch) configs, every one
    // asserting the full equivalence chain. A failure names the config.
    let sw = SweepConfig { cases: 25, seed: 0xD1FF, iters: 3, ..Default::default() };
    let report = run_sweep(&sw).unwrap();
    assert_eq!(report.cases.len(), 25);
    assert!(
        report.max_loss_dev <= sw.loss_rtol,
        "distributed vs oracle loss deviation {:.3e}",
        report.max_loss_dev
    );
    assert!(report.max_grad_dev <= sw.grad_rtol);
    assert!(report.max_forward_dev <= sw.forward_rtol);
    // The sweep covers both optimism directions: some PP-favored and some
    // deeper/shallower geometries actually got sampled.
    let layers: std::collections::BTreeSet<usize> =
        report.cases.iter().map(|c| c.layers).collect();
    assert!(layers.len() > 1, "sweep degenerated to a single depth: {layers:?}");

    // Refresh the repo-root bench record (uploaded as a CI artifact).
    let records = report.records();
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../BENCH_conformance.json");
    let meta = phantom::util::json::BenchMeta::new("conformance", 0.0);
    phantom::serve::write_records_json_with_meta(&path, &records, &meta).unwrap();
    let back = read_records_json(&path).unwrap();
    assert_eq!(back.len(), records.len());
}

#[test]
fn fault_plan_generation_is_byte_identical_across_runs() {
    let spec = StormSpec {
        p: 4,
        horizon: 24,
        events: 10,
        mean_delay_s: 2e-3,
        allow_drops: true,
        allow_poison: true,
    };
    let a = FaultPlan::generate(0xC4A05, &spec);
    let b = FaultPlan::generate(0xC4A05, &spec);
    assert!(!a.is_empty());
    assert_eq!(
        a.canonical_bytes(),
        b.canonical_bytes(),
        "same seed must reproduce the same schedule, byte for byte"
    );
}

#[test]
fn same_fault_plan_fires_byte_identically_and_preserves_the_math() {
    // A delay-only storm: non-fatal, so training completes. Two runs under
    // plans generated from the same seed must (a) fire the same faults at
    // the same collectives — byte-identical logs — and (b) leave the loss
    // trajectory exactly equal to the fault-free run: injected faults live
    // in virtual time, never in the math.
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 4;
    let server = ExecServer::for_run(&cfg).unwrap();
    let spec = StormSpec {
        p: cfg.p,
        horizon: 16, // 4 iters x 4 collectives/iter
        events: 6,
        mean_delay_s: 5e-3,
        allow_drops: false,
        allow_poison: false,
    };

    let clean = train_with(&cfg, &server, TrainOptions::default()).unwrap();

    let mut fired = Vec::new();
    for _ in 0..2 {
        let plan = FaultPlan::generate(0xB00, &spec);
        let opts = TrainOptions { faults: Some(plan.injector_factory()), ..Default::default() };
        let report = train_with(&cfg, &server, opts).unwrap();
        assert_eq!(
            report.losses, clean.losses,
            "virtual-time faults must not perturb the training math"
        );
        // Every scheduled event fired (the run covers the whole horizon),
        // at exactly the scheduled (rank, seq) points.
        let fired_keys: Vec<(usize, u64)> =
            plan.fired().iter().map(|f| (f.rank, f.seq)).collect();
        let planned_keys: Vec<(usize, u64)> =
            plan.events().iter().map(|e| (e.rank, e.seq)).collect();
        assert_eq!(fired_keys, planned_keys, "schedule and firings must agree");
        fired.push(plan.fired_bytes());
    }
    assert_eq!(fired[0], fired[1], "fired-fault logs must be byte-identical across runs");
    // (The virtual-time arithmetic of a single injected delay — straggler
    // idle on the delayed rank, matching rendezvous wait on its peers — is
    // asserted exactly in comm::tests::injected_delay_stalls_straggler...,
    // where no measured compute time muddies the comparison.)
}

#[test]
fn determinism_contract_includes_a_crash_resume_trajectory_match() {
    let cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    let dir = std::env::temp_dir()
        .join(format!("phantom-conformance-resume-{}", std::process::id()));
    let report = train_crash_resume(&cfg, 6, 2, 1, 3, &dir).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(report.resumed_from, 2, "crash at iter 3 resumes from the iter-2 snapshot");
    assert!(
        report.bit_identical,
        "resumed trajectory diverged: {:?} vs {:?}",
        report.resumed, report.baseline
    );
    assert!(report.crash_error.contains("rank 1 panicked"), "{}", report.crash_error);
    assert!(report.crash_error.contains("injected fault"), "{}", report.crash_error);
}
