//! Property tests for the collective fabric (ISSUE 2 hardening pass,
//! extended by the ISSUE 4 testkit pass):
//!
//! * mis-sequenced collectives poison the exchange and error LOUDLY — the
//!   whole suite runs in seconds, never a 60 s rendezvous hang, thanks to
//!   `Fabric::with_timeout`;
//! * virtual clocks advance monotonically through random collective
//!   sequences and end aligned across ranks;
//! * All-Gather followed by a 1/p-scaled Reduce-Scatter is the identity on
//!   ragged (odd-sized, non-power-of-two) shard shapes;
//! * All-Reduce agrees bitwise with a sequential rank-ordered reduction on
//!   ragged shapes, is commutative across rank orderings within float
//!   tolerance, and `all_reduce_scalar` matches the same contract.

use std::sync::Arc;
use std::time::{Duration, Instant};

use phantom::comm::{Endpoint, Fabric};
use phantom::energy::{Activity, EnergyLedger};
use phantom::simnet::NetworkProfile;
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;
use phantom::util::proptest::{assert_close, check, PropConfig};

/// Run one closure per rank on its own thread; returns per-rank results in
/// rank order. Thin wrapper over `Fabric::run_ranks`, which propagates a
/// panicking rank as a structured error instead of a bare join unwrap.
fn run_ranks<T: Send + 'static>(
    p: usize,
    timeout: Duration,
    f: impl Fn(Endpoint, EnergyLedger) -> T + Send + Sync + 'static,
) -> Vec<T> {
    Fabric::run_ranks(p, NetworkProfile::frontier(), timeout, f)
        .unwrap_or_else(|e| panic!("{e}"))
}

#[test]
fn mis_sequenced_collectives_error_loudly_not_hang() {
    let t0 = Instant::now();
    let cfg = PropConfig { cases: 8, ..PropConfig::default() };
    check("collective mismatch poisons", cfg, |rng| {
        let p = rng.int_in(2, 4) as usize;
        // Rank `odd_rank` calls a different collective than its peers.
        let odd_rank = rng.int_in(0, p as u64 - 1) as usize;
        let swap = rng.int_in(0, 1) == 0;
        let out = run_ranks(p, Duration::from_millis(250), move |mut ep, mut led| {
            let t = Tensor::filled(&[2], 1.0);
            let mine_odd = ep.rank == odd_rank;
            let r = if mine_odd != swap {
                ep.all_reduce(t, &mut led).map(|_| ())
            } else {
                ep.all_gather(t, &mut led).map(|_| ())
            };
            // After a poisoning, every later collective must fail fast too.
            let after = ep.all_reduce(Tensor::filled(&[2], 1.0), &mut led);
            (r, after.map(|_| ()))
        });
        if !out.iter().any(|(r, _)| r.is_err()) {
            return Err("mismatch must surface as at least one error".into());
        }
        if let Some((i, _)) = out.iter().enumerate().find(|(_, (_, a))| a.is_ok()) {
            return Err(format!("rank {i}: collective succeeded on a poisoned fabric"));
        }
        Ok(())
    });
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "mismatches must fail in milliseconds, not rendezvous-timeout hangs"
    );
}

#[test]
fn absent_peer_times_out_loudly_not_hang() {
    let t0 = Instant::now();
    // Rank 1 never shows up; rank 0 must get a timeout error, promptly.
    let out = run_ranks(2, Duration::from_millis(200), |mut ep, mut led| {
        if ep.rank == 0 {
            ep.all_reduce(Tensor::filled(&[4], 1.0), &mut led).map(|_| ())
        } else {
            Ok(()) // deserter
        }
    });
    assert!(out[0].is_err(), "the waiting rank must error, not hang");
    let msg = format!("{:#}", out[0].as_ref().unwrap_err());
    assert!(msg.contains("timeout"), "error should name the timeout: {msg}");
    assert!(t0.elapsed() < Duration::from_secs(10));
}

#[test]
fn virtual_clocks_monotone_and_aligned() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("clock monotonicity", cfg, |rng| {
        let p = rng.int_in(2, 5) as usize;
        let rounds = rng.int_in(2, 7) as usize;
        // Per-round op id, shape, and per-rank compute skew.
        let plan: Vec<(u64, usize, usize, f64)> = (0..rounds)
            .map(|_| {
                (
                    rng.int_in(0, 2),
                    rng.int_in(1, 5) as usize,
                    rng.int_in(1, 6) as usize,
                    rng.next_f64() * 1e-3,
                )
            })
            .collect();
        let plan = Arc::new(plan);
        let out = run_ranks(p, Duration::from_secs(60), move |mut ep, mut led| {
            let mut clocks = vec![led.now_s];
            for &(op, a, b, work) in plan.iter() {
                led.advance(work * (ep.rank + 1) as f64, Activity::Compute);
                match op {
                    0 => {
                        ep.all_gather(Tensor::filled(&[a, b], 1.0), &mut led).unwrap();
                    }
                    1 => {
                        let mut shape = vec![ep.p];
                        shape.extend_from_slice(&[a, b]);
                        ep.reduce_scatter(Tensor::filled(&shape, 1.0), &mut led).unwrap();
                    }
                    _ => {
                        ep.all_reduce(Tensor::filled(&[a, b], 1.0), &mut led).unwrap();
                    }
                }
                clocks.push(led.now_s);
            }
            clocks
        });
        for (rank, clocks) in out.iter().enumerate() {
            for w in clocks.windows(2) {
                if w[1] < w[0] {
                    return Err(format!("rank {rank}: clock regressed {} -> {}", w[0], w[1]));
                }
            }
        }
        // Synchronous collectives leave every rank at the same post-round
        // clock (the max-arrival + wire-time rendezvous rule).
        for round in 1..out[0].len() {
            let t0 = out[0][round];
            for (rank, clocks) in out.iter().enumerate() {
                if (clocks[round] - t0).abs() > 1e-12 {
                    return Err(format!(
                        "round {round}: rank {rank} clock {} != rank 0 clock {t0}",
                        clocks[round]
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Per-rank contribution for the all-reduce properties: ragged shape,
/// seeded values, optionally permuted so rank r contributes slot perm[r].
fn contribution(shape: &[usize], seed: u64, slot: usize) -> Tensor {
    let mut rng = Prng::new(seed ^ (slot as u64).wrapping_mul(0x9E3779B97F4A7C15));
    Tensor::randn(shape, 1.0, &mut rng)
}

#[test]
fn all_reduce_matches_sequential_reduction_on_ragged_shapes() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("all-reduce == sequential rank-ordered sum", cfg, |rng| {
        let p = rng.int_in(2, 6) as usize;
        let shape = vec![
            (2 * rng.int_in(0, 3) + 1) as usize,
            (2 * rng.int_in(0, 6) + 1) as usize,
        ];
        let seed = rng.next_u64();
        let shape_arc = Arc::new(shape.clone());
        let out = run_ranks(p, Duration::from_secs(60), move |mut ep, mut led| {
            let t = contribution(shape_arc.as_slice(), seed, ep.rank);
            ep.all_reduce(t, &mut led).unwrap()
        });
        // Sequential reference: fold the contributions in rank order — the
        // exact order the fabric's last-arriver combine uses, so agreement
        // is bitwise, not just approximate.
        let mut want = contribution(&shape, seed, 0);
        for slot in 1..p {
            want.add_assign(&contribution(&shape, seed, slot));
        }
        for (rank, r) in out.iter().enumerate() {
            if r.shape() != want.shape() {
                return Err(format!("rank {rank}: shape {:?} != {:?}", r.shape(), want.shape()));
            }
            for (i, (a, b)) in r.data().iter().zip(want.data()).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "rank {rank} [{i}]: {a} != sequential {b} (bitwise contract)"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn all_reduce_is_commutative_across_rank_orderings() {
    let cfg = PropConfig { cases: 16, ..PropConfig::default() };
    check("all-reduce rank-permutation commutativity", cfg, |rng| {
        let p = rng.int_in(2, 5) as usize;
        let shape = vec![
            (2 * rng.int_in(0, 2) + 1) as usize,
            (2 * rng.int_in(0, 4) + 1) as usize,
        ];
        let seed = rng.next_u64();
        // A random permutation: rank r contributes slot perm[r].
        let mut perm: Vec<usize> = (0..p).collect();
        for i in (1..p).rev() {
            perm.swap(i, rng.int_in(0, i as u64) as usize);
        }
        let run = |assignment: Vec<usize>| {
            let shape = Arc::new(shape.clone());
            let assignment = Arc::new(assignment);
            run_ranks(p, Duration::from_secs(60), move |mut ep, mut led| {
                let t = contribution(shape.as_slice(), seed, assignment[ep.rank]);
                ep.all_reduce(t, &mut led).unwrap()
            })
        };
        let identity = run((0..p).collect());
        let permuted = run(perm.clone());
        for (rank, (a, b)) in identity.iter().zip(&permuted).enumerate() {
            assert_close(a.data(), b.data(), 1e-5, 1e-6).map_err(|e| {
                format!("rank {rank}: permuted sum diverged (perm {perm:?}): {e}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn all_reduce_scalar_matches_sequential_f32_sum() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("all-reduce-scalar == sequential f32 sum", cfg, |rng| {
        let p = rng.int_in(2, 6) as usize;
        let seed = rng.next_u64();
        let value = |rank: usize| -> f32 {
            let mut r = Prng::new(seed ^ (rank as u64).wrapping_mul(0xD1B5));
            (r.next_f64() * 2.0 - 1.0) as f32
        };
        let out = run_ranks(p, Duration::from_secs(60), move |mut ep, mut led| {
            ep.all_reduce_scalar(value(ep.rank), &mut led).unwrap()
        });
        let mut want = value(0);
        for rank in 1..p {
            want += value(rank);
        }
        for (rank, &got) in out.iter().enumerate() {
            if got.to_bits() != want.to_bits() {
                return Err(format!("rank {rank}: scalar {got} != sequential {want}"));
            }
        }
        Ok(())
    });
}

#[test]
fn zero_reduce_scatter_all_gather_matches_all_reduce_bitwise() {
    // The ZeRO-1 identity: reduce-scattering the zero-padded [dp, slot]
    // view of a flat gradient delivers each rank the SAME bits the flat
    // all-reduce would (both fold contributions in rank order), and the
    // all-gather of the slices reassembles the full all-reduced vector
    // bitwise. This is the whole bit-exactness argument for sharded
    // optimizer states — exercised on ragged totals that p does not
    // divide, so the zero pad is live.
    let cfg = PropConfig { cases: 16, ..PropConfig::default() };
    check("reduce_scatter . all_gather == all_reduce (bitwise)", cfg, |rng| {
        let p = rng.int_in(2, 5) as usize;
        let total = rng.int_in(1, 37) as usize;
        let seed = rng.next_u64();
        let out = run_ranks(p, Duration::from_secs(60), move |mut ep, mut led| {
            let mine = contribution(&[total], seed, ep.rank);
            let reduced = ep.all_reduce(mine.clone(), &mut led).unwrap();
            let stacked = phantom::coordinator::zero::pad_stack(&mine, ep.p);
            let own = ep.dp_reduce_scatter(stacked, &mut led).unwrap();
            let slot = own.numel();
            // Own slice must equal the matching window of the all-reduce.
            let lo = (ep.rank * slot).min(total);
            let hi = ((ep.rank + 1) * slot).min(total);
            for (i, &x) in own.data()[..hi - lo].iter().enumerate() {
                assert_eq!(
                    x.to_bits(),
                    reduced.data()[lo + i].to_bits(),
                    "rank {} slice [{i}] diverged from the all-reduce",
                    ep.rank
                );
            }
            for &x in &own.data()[hi - lo..] {
                assert_eq!(x, 0.0, "zero pad must reduce to zero");
            }
            let gathered = ep.dp_all_gather(own, &mut led).unwrap();
            (reduced, gathered)
        });
        for (rank, (reduced, gathered)) in out.iter().enumerate() {
            if gathered.numel() < total {
                return Err(format!(
                    "rank {rank}: gathered {} floats for total {total}",
                    gathered.numel()
                ));
            }
            for (i, (a, b)) in gathered.data()[..total].iter().zip(reduced.data()).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "rank {rank} [{i}]: RS.AG {a} != all-reduce {b} (bitwise contract)"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn zero_flat_slice_tiling_roundtrips_ragged_totals() {
    // Host-side half of the ZeRO contract: flatten -> per-rank read_slice
    // windows tile the flat vector exactly (zero-padded past the end),
    // and unflatten_into is the inverse of flatten for any ragged
    // shape list whose total the replica count does not divide.
    use phantom::coordinator::zero;
    let cfg = PropConfig { cases: 32, ..PropConfig::default() };
    check("zero helpers tile ragged totals", cfg, |rng| {
        let dp = rng.int_in(1, 5) as usize;
        let n_tensors = rng.int_in(1, 5) as usize;
        let shapes: Vec<Vec<usize>> = (0..n_tensors)
            .map(|_| vec![rng.int_in(1, 4) as usize, (2 * rng.int_in(0, 3) + 1) as usize])
            .collect();
        let mut prng = Prng::new(rng.next_u64());
        let mut tensors: Vec<Tensor> =
            shapes.iter().map(|s| Tensor::randn(s, 1.0, &mut prng)).collect();
        let flat = zero::flatten(&tensors);
        let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        if flat.numel() != total {
            return Err(format!("flatten produced {} floats, want {total}", flat.numel()));
        }
        let slot = zero::slot_len(total, dp);
        if slot * dp < total || slot * dp >= total + dp.max(2) {
            return Err(format!("slot_len({total}, {dp}) = {slot} does not tile"));
        }
        // The dp read_slice windows concatenate back to flat + zero pad.
        let mut refs: Vec<&mut Tensor> = tensors.iter_mut().collect();
        let mut rebuilt: Vec<f32> = Vec::with_capacity(dp * slot);
        for d in 0..dp {
            rebuilt.extend_from_slice(zero::read_slice(&refs, d * slot, slot).data());
        }
        for (i, &x) in rebuilt.iter().enumerate() {
            let want = if i < total { flat.data()[i] } else { 0.0 };
            if x.to_bits() != want.to_bits() {
                return Err(format!("slice tiling [{i}]: {x} != {want}"));
            }
        }
        // unflatten_into inverts flatten, tolerating trailing pad.
        let padded = Tensor::from_vec(&[dp * slot], rebuilt).unwrap();
        let before: Vec<Vec<f32>> = refs.iter().map(|t| t.data().to_vec()).collect();
        for t in refs.iter_mut() {
            t.data_mut().iter_mut().for_each(|x| *x = f32::NAN);
        }
        zero::unflatten_into(&padded, &mut refs);
        for (t, want) in refs.iter().zip(&before) {
            for (a, b) in t.data().iter().zip(want) {
                if a.to_bits() != b.to_bits() {
                    return Err("unflatten_into failed to invert flatten".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn reduce_scatter_is_commutative_across_rank_orderings() {
    // Slot j's sum folds contributions in rank order; permuting which rank
    // contributes which stack changes only the f32 fold order, so results
    // agree within float tolerance (the same contract all_reduce keeps).
    let cfg = PropConfig { cases: 16, ..PropConfig::default() };
    check("reduce-scatter rank-permutation commutativity", cfg, |rng| {
        let p = rng.int_in(2, 5) as usize;
        let slot_shape = vec![(2 * rng.int_in(0, 2) + 1) as usize, (2 * rng.int_in(0, 4) + 1) as usize];
        let seed = rng.next_u64();
        let mut perm: Vec<usize> = (0..p).collect();
        for i in (1..p).rev() {
            perm.swap(i, rng.int_in(0, i as u64) as usize);
        }
        let run = |assignment: Vec<usize>| {
            let slot_shape = Arc::new(slot_shape.clone());
            let assignment = Arc::new(assignment);
            run_ranks(p, Duration::from_secs(60), move |mut ep, mut led| {
                // Stack [p, ...slot_shape], seeded per (contributor, slot).
                let mut stack_shape = vec![ep.p];
                stack_shape.extend_from_slice(&slot_shape);
                let mut stack = Tensor::zeros(&stack_shape);
                let slot_n: usize = slot_shape.iter().product();
                for j in 0..ep.p {
                    let c = contribution(
                        &slot_shape,
                        seed ^ (assignment[ep.rank] as u64).wrapping_mul(0xABCD),
                        j,
                    );
                    stack.data_mut()[j * slot_n..(j + 1) * slot_n].copy_from_slice(c.data());
                }
                ep.reduce_scatter(stack, &mut led).unwrap()
            })
        };
        let identity = run((0..p).collect());
        let permuted = run(perm.clone());
        for (rank, (a, b)) in identity.iter().zip(&permuted).enumerate() {
            assert_close(a.data(), b.data(), 1e-5, 1e-6).map_err(|e| {
                format!("rank {rank}: permuted reduce-scatter diverged (perm {perm:?}): {e}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn zero_collective_mismatch_poisons_fabric() {
    // SPMD safety for the ZeRO traffic: a rank calling dp_all_gather while
    // its peers call dp_reduce_scatter is a programming error that must
    // poison the exchange loudly (distinct op tags make it detectable),
    // and every later collective on the poisoned fabric must fail fast.
    let t0 = Instant::now();
    let out = run_ranks(3, Duration::from_millis(250), |mut ep, mut led| {
        let r = if ep.rank == 0 {
            ep.dp_all_gather(Tensor::filled(&[4], 1.0), &mut led).map(|_| ())
        } else {
            ep.dp_reduce_scatter(Tensor::filled(&[3, 4], 1.0), &mut led).map(|_| ())
        };
        let after = ep.dp_all_gather(Tensor::filled(&[4], 1.0), &mut led);
        (r, after.map(|_| ()))
    });
    assert!(
        out.iter().any(|(r, _)| r.is_err()),
        "dp op mismatch must surface as at least one error"
    );
    for (i, (_, after)) in out.iter().enumerate() {
        assert!(after.is_err(), "rank {i}: dp collective succeeded on a poisoned fabric");
    }
    assert!(t0.elapsed() < Duration::from_secs(10), "poison must fail fast, not hang");
}

#[test]
fn gather_scatter_roundtrip_is_identity_on_ragged_shapes() {
    let cfg = PropConfig { cases: 24, ..PropConfig::default() };
    check("all-gather/reduce-scatter round-trip", cfg, |rng| {
        let p = rng.int_in(2, 6) as usize;
        // Ragged: odd, non-power-of-two dims, sometimes degenerate width 1.
        let shape = vec![
            (2 * rng.int_in(0, 3) + 1) as usize,
            (2 * rng.int_in(0, 6) + 1) as usize,
        ];
        let seed = rng.next_u64();
        let shape_arc = Arc::new(shape);
        let out = run_ranks(p, Duration::from_secs(60), move |mut ep, mut led| {
            let mut r =
                phantom::util::prng::Prng::new(seed ^ (ep.rank as u64).wrapping_mul(0x9E37));
            let t = Tensor::randn(shape_arc.as_slice(), 1.0, &mut r);
            let mut gathered = ep.all_gather(t.clone(), &mut led).unwrap();
            // Every rank holds the identical [p, ...] stack; scaling by 1/p
            // and reduce-scattering sums p copies of slot_j / p = slot_j,
            // delivering rank j's original contribution back to rank j.
            gathered.scale(1.0 / ep.p as f32);
            let back = ep.reduce_scatter(gathered, &mut led).unwrap();
            (t, back)
        });
        for (rank, (t, back)) in out.iter().enumerate() {
            if back.shape() != t.shape() {
                return Err(format!(
                    "rank {rank}: round-trip shape {:?} != {:?}",
                    back.shape(),
                    t.shape()
                ));
            }
            assert_close(back.data(), t.data(), 1e-5, 1e-6)
                .map_err(|e| format!("rank {rank}: {e}"))?;
        }
        Ok(())
    });
}
