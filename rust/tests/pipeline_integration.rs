//! Integration tests over the full stack: backend kernels -> collective
//! fabric -> coordinator pipelines.
//!
//! DESIGN.md §6 invariants 1-3 and 5, end-to-end through the native
//! backend — these run self-contained on a clean machine (no artifact
//! bundle, no libxla). The one PJRT-specific test (pallas-variant parity)
//! is gated behind the `xla` cargo feature and skips without artifacts.

use phantom::config::{preset, Parallelism, RunConfig};
use phantom::coordinator::{self, driver::pp_forward_once};
use phantom::model::DensePhantomOracle;
use phantom::runtime::ExecServer;
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;

/// Invariant 1: the p-rank sharded phantom forward equals the monolithic
/// dense-equivalent oracle.
#[test]
fn pp_sharded_forward_equals_dense_oracle() {
    let server = ExecServer::native();
    for name in ["tiny", "tiny_p2"] {
        let cfg = preset(name, Parallelism::Phantom).unwrap();
        let mut rng = Prng::new(99);
        let x = Tensor::randn(&[cfg.train.batch, cfg.model.n], 1.0, &mut rng);

        let y_sharded = pp_forward_once(&cfg, &server, &x).unwrap();
        let oracle = DensePhantomOracle::init(&cfg.model, cfg.p, cfg.train.seed).unwrap();
        let y_dense = oracle.forward(&x).unwrap();

        assert_eq!(y_sharded.shape(), y_dense.shape());
        phantom::util::proptest::assert_close(y_sharded.data(), y_dense.data(), 1e-4, 1e-5)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

/// Invariant: training runs end-to-end and the loss decreases (both modes).
#[test]
fn training_reduces_loss_both_modes() {
    let server = ExecServer::native();
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let mut cfg = preset("tiny", mode).unwrap();
        cfg.train.max_iters = 30;
        let report = coordinator::train(&cfg, &server).unwrap();
        assert_eq!(report.iterations, 30);
        let first = report.losses[0];
        let last = *report.losses.last().unwrap();
        assert!(
            last < first * 0.9,
            "{:?}: loss did not decrease: {first} -> {last}",
            mode
        );
        // Per-rank accounting sanity.
        assert_eq!(report.per_rank.len(), cfg.p);
        for r in &report.per_rank {
            assert!(r.ledger.busy_s > 0.0, "rank {} never computed", r.rank);
            assert!(r.stats.comm_s > 0.0, "rank {} never communicated", r.rank);
        }
        assert!(report.energy_total_j > 0.0);
        assert!(report.energy_train_j <= report.energy_total_j);
    }
}

/// The headline acceptance run: a p=4, 2-layer PP-vs-TP comparison
/// completes end-to-end on the native backend with no artifacts directory
/// and no libxla, and PP moves fewer floats than TP (paper Table II).
#[test]
fn native_quickstart_pp_vs_tp_end_to_end() {
    let server = ExecServer::native();
    assert_eq!(server.backend_name(), "native");
    let mut floats = std::collections::HashMap::new();
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let mut cfg = preset("quickstart", mode).unwrap();
        assert_eq!(cfg.p, 4);
        assert_eq!(cfg.model.layers, 2);
        cfg.train.max_iters = 6;
        let r = coordinator::train(&cfg, &server).unwrap();
        assert_eq!(r.iterations, 6);
        assert!(r.losses.last().unwrap() < r.losses.first().unwrap());
        floats.insert(
            mode.name(),
            r.per_rank.iter().map(|x| x.stats.floats_moved).sum::<u64>(),
        );
    }
    assert!(
        floats["pp"] < floats["tp"],
        "PP must move fewer floats than TP: pp={} tp={}",
        floats["pp"],
        floats["tp"]
    );
}

/// Same loss trajectory across repeated runs (full determinism).
#[test]
fn training_is_deterministic() {
    let server = ExecServer::native();
    let mut cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 10;
    let a = coordinator::train(&cfg, &server).unwrap();
    let b = coordinator::train(&cfg, &server).unwrap();
    assert_eq!(a.losses, b.losses);
}

/// PP moves strictly fewer floats than TP at every scale (Table II), and at
/// bandwidth-relevant sizes its modeled comm time is lower too (Eqn. 9 /
/// Fig. 5a). At tiny latency-bound sizes the two CONVERGE (the paper's own
/// Fig. 5b observation: "the bandwidth-bound communication costs of both
/// approaches become comparable") — so the seconds assertion uses `medium`.
#[test]
fn pp_comm_less_than_tp() {
    let server = ExecServer::native();
    // floats-on-the-wire: PP < TP even at tiny scale
    let mut pp = preset("tiny", Parallelism::Phantom).unwrap();
    let mut tp = preset("tiny", Parallelism::Tensor).unwrap();
    pp.train.max_iters = 3;
    tp.train.max_iters = 3;
    let rp = coordinator::train(&pp, &server).unwrap();
    let rt = coordinator::train(&tp, &server).unwrap();
    let pp_floats: u64 = rp.per_rank.iter().map(|r| r.stats.floats_moved).sum();
    let tp_floats: u64 = rt.per_rank.iter().map(|r| r.stats.floats_moved).sum();
    assert!(pp_floats < tp_floats, "pp={pp_floats} tp={tp_floats}");

    // modeled comm seconds: PP < TP once messages are bandwidth-relevant
    let mut pp = preset("medium", Parallelism::Phantom).unwrap();
    let mut tp = preset("medium", Parallelism::Tensor).unwrap();
    pp.train.max_iters = 2;
    tp.train.max_iters = 2;
    let rp = coordinator::train(&pp, &server).unwrap();
    let rt = coordinator::train(&tp, &server).unwrap();
    let pp_comm: f64 = rp.per_rank.iter().map(|r| r.stats.comm_s).sum();
    let tp_comm: f64 = rt.per_rank.iter().map(|r| r.stats.comm_s).sum();
    assert!(pp_comm < tp_comm, "pp_comm={pp_comm} tp_comm={tp_comm}");
}

/// The PP model is smaller than the TP model when Eqn. (8) holds.
#[test]
fn pp_model_smaller() {
    let server = ExecServer::native();
    let mut pp = preset("tiny", Parallelism::Phantom).unwrap();
    let mut tp = preset("tiny", Parallelism::Tensor).unwrap();
    pp.train.max_iters = 1;
    tp.train.max_iters = 1;
    let rp = coordinator::train(&pp, &server).unwrap();
    let rt = coordinator::train(&tp, &server).unwrap();
    assert!(rp.model_params < rt.model_params);
}

/// Fixed-loss stopping: run PP to a target reachable within the cap.
#[test]
fn fixed_loss_stopping_works() {
    let server = ExecServer::native();
    let mut cfg = preset("tiny", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = 200;
    // First run to discover a reachable loss value.
    let mut probe = cfg.clone();
    probe.train.max_iters = 40;
    let r = coordinator::train(&probe, &server).unwrap();
    let target = r.losses.last().unwrap() * 1.05;
    cfg.train.target_loss = Some(target);
    let r2 = coordinator::train(&cfg, &server).unwrap();
    assert!(r2.reached_target, "should reach {target}");
    assert!(r2.iterations <= 40, "stopped at {}", r2.iterations);
}

/// Geometry mismatch between run config and artifact bundle is rejected.
#[test]
fn artifact_geometry_mismatch_rejected() {
    let server = ExecServer::native();
    let mut cfg = preset("tiny", Parallelism::Phantom).unwrap();
    cfg.artifact = Some("tiny_p2".into()); // wrong p/n/batch
    let err = coordinator::train(&cfg, &server).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("does not match"), "{msg}");
}

/// A custom (non-preset) geometry trains through ExecServer::native_for,
/// which registers the run's own synthetic config.
#[test]
fn native_for_serves_custom_geometry() {
    let mut cfg = preset("tiny", Parallelism::Phantom).unwrap();
    cfg.model.n = 96; // not a preset geometry
    cfg.model.k = 3;
    cfg.train.batch = 4;
    cfg.artifact = Some("custom96".into());
    cfg.train.max_iters = 2;
    let server = ExecServer::native_for(&cfg).unwrap();
    let r = coordinator::train(&cfg, &server).unwrap();
    assert_eq!(r.iterations, 2);
    assert_eq!(r.n, 96);
}

/// The pallas-kernel artifact variant produces the same numbers as the
/// jnp variant (L1 integration through PJRT, not just pytest). Needs the
/// `xla` feature and a built artifact bundle; skipped otherwise.
#[cfg(feature = "xla")]
#[test]
fn pallas_variant_matches_jnp_through_pjrt() {
    let dir = phantom::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return;
    }
    let server = ExecServer::start(dir).expect("exec server");
    let mut jnp = preset("tiny_p2", Parallelism::Phantom).unwrap();
    jnp.train.max_iters = 5;
    let mut pal = jnp.clone();
    pal.artifact = Some("tiny_p2_pallas".into());
    let rj = coordinator::train(&jnp, &server).unwrap();
    let rp = coordinator::train(&pal, &server).unwrap();
    for (a, b) in rj.losses.iter().zip(&rp.losses) {
        assert!((a - b).abs() < 1e-6 * a.abs().max(1.0), "{a} vs {b}");
    }
}

/// RunConfig validation rejects k >= n/p (Eqn. 8 hard bound).
#[test]
fn config_validation() {
    let mut cfg: RunConfig = preset("tiny", Parallelism::Phantom).unwrap();
    cfg.model.k = cfg.model.n / cfg.p;
    assert!(cfg.validate().is_err());
}
