//! Compatibility contract for the GEMM tuning manifest, mirroring the ckpt
//! manifest tests: round-trip fidelity, unknown fields ignored, missing
//! fields defaulted, version bumps rejected with a clear error, and the
//! missing-file → defaults fallback that makes deleting the manifest always
//! safe.

use std::collections::BTreeMap;

use phantom::tensor::simd;
use phantom::tensor::tune::{self, class_key, class_name, GemmParams, Tuning, TUNE_MANIFEST_NAME};

fn sample_tuning() -> Tuning {
    let mut classes = BTreeMap::new();
    classes.insert(
        class_key(512, 512, 512),
        GemmParams { mr: 8, kc: 128, jc: 256, max_bands: 4, par_min_flops: 1 << 20 },
    );
    classes.insert(
        class_key(32, 256, 256),
        GemmParams { mr: 4, kc: 256, jc: 512, max_bands: 0, par_min_flops: 1 << 22 },
    );
    Tuning { isa: "avx2+fma".to_string(), classes }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("phantom-tune-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn roundtrips_through_disk() {
    let dir = tmp_dir("roundtrip");
    let path = dir.join(TUNE_MANIFEST_NAME);
    let t = sample_tuning();
    t.save(&path).unwrap();
    let back = Tuning::load(&path).unwrap().expect("manifest exists");
    assert_eq!(back, t);
    // The serialized form is stable, diffable JSON with named classes.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"version\": 1"), "{text}");
    assert!(text.contains("\"m512_k512_n512\""), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_file_is_defaults_not_error() {
    let dir = tmp_dir("missing");
    let path = dir.join("does-not-exist.json");
    assert!(Tuning::load(&path).unwrap().is_none());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_fields_are_ignored() {
    // A manifest written by a future build with extra fields must load:
    // only the fields this build knows are read.
    let text = r#"{
      "version": 1,
      "isa": "avx2+fma",
      "written_by": "phantom 9.9",
      "classes": {
        "m64_k64_n64": {"mr": 8, "kc": 128, "jc": 256, "max_bands": 2,
                        "par_min_flops": 1024, "simd_width": 16}
      }
    }"#;
    let t = Tuning::parse(text).unwrap();
    let p = t.classes[&(64, 64, 64)];
    assert_eq!(p.mr, 8);
    assert_eq!(p.kc, 128);
    assert_eq!(p.jc, 256);
    assert_eq!(p.max_bands, 2);
    assert_eq!(p.par_min_flops, 1024);
}

#[test]
fn missing_fields_take_defaults() {
    let text = r#"{
      "version": 1,
      "classes": {"m128_k128_n128": {"kc": 64}}
    }"#;
    let t = Tuning::parse(text).unwrap();
    let p = t.classes[&(128, 128, 128)];
    let base = GemmParams::default_for(simd::active());
    assert_eq!(p.kc, 64);
    assert_eq!(p.mr, base.mr);
    assert_eq!(p.jc, base.jc);
    assert_eq!(p.max_bands, base.max_bands);
    assert_eq!(p.par_min_flops, base.par_min_flops);
    assert_eq!(t.isa, "unknown");
}

#[test]
fn hostile_values_are_sanitized_and_bad_keys_skipped() {
    let text = r#"{
      "version": 1,
      "isa": "portable",
      "classes": {
        "m16_k16_n16": {"mr": 0, "kc": 0, "jc": 999999999},
        "not_a_class": {"mr": 8}
      }
    }"#;
    let t = Tuning::parse(text).unwrap();
    assert_eq!(t.classes.len(), 1, "malformed key must be skipped, not fatal");
    let p = t.classes[&(16, 16, 16)];
    assert_eq!(p.mr, 4, "mr clamped to a supported microkernel height");
    assert!(p.kc >= 8 && p.jc <= 1 << 16, "blocking clamped: {p:?}");
}

#[test]
fn version_bump_is_rejected_with_clear_error() {
    let err = Tuning::parse(r#"{"version": 2, "classes": {}}"#).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("version 2"), "error must name the bad version: {msg}");
    assert!(msg.contains("phantom tune"), "error must say how to recover: {msg}");
    assert!(Tuning::parse(r#"{"classes": {}}"#).is_err(), "missing version must be rejected");
    assert!(Tuning::parse("{not json").is_err());
}

#[test]
fn installed_tuning_changes_params_and_clears_back_to_defaults() {
    // All process-global assertions live in this one test: integration
    // tests in one binary run on parallel threads, and the install/clear
    // global is shared.
    let isa = simd::active();
    let defaults = GemmParams::default_for(isa);

    let mut t = sample_tuning();
    let tuned = GemmParams { mr: 4, kc: 32, jc: 64, max_bands: 1, par_min_flops: 1 };
    t.classes.insert(class_key(100, 100, 100), tuned);
    tune::install(t);
    assert_eq!(tune::params_for(100, 100, 100), tuned, "class hit must use tuned params");
    assert_eq!(tune::params_for(100, 128, 100), tuned, "same bucket, same params");
    assert_eq!(
        tune::params_for(2000, 2000, 2000),
        defaults,
        "class miss must fall back to ISA defaults"
    );
    assert!(tune::installed_classes() >= 3);

    tune::clear_installed();
    assert_eq!(tune::installed_classes(), 0);
    assert_eq!(tune::params_for(100, 100, 100), defaults, "cleared tuning = defaults");

    // An end-to-end CLI-shaped cycle: autotune tiny shapes on the quick
    // grid, save, reload, install, observe the configured difference.
    let dir = tmp_dir("cycle");
    let path = dir.join(TUNE_MANIFEST_NAME);
    let (tuning, outcomes) = tune::autotune(&[(16, 32, 32)], 1, true);
    assert_eq!(outcomes.len(), 1);
    assert!(outcomes[0].candidates > 1);
    tuning.save(&path).unwrap();
    let back = Tuning::load(&path).unwrap().expect("saved manifest loads");
    assert_eq!(back.classes.len(), tuning.classes.len());
    assert_eq!(back.isa, isa.name());
    let key = class_key(16, 32, 32);
    assert!(back.classes.contains_key(&key), "missing {}", class_name(key));
    tune::install(back);
    let got = tune::params_for(16, 32, 32);
    assert_eq!(got, tuning.classes[&key].sanitized(), "fresh-process params must be the winner");
    tune::clear_installed();
    std::fs::remove_dir_all(&dir).ok();
}
