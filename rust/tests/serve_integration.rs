//! End-to-end serve-subsystem integration (DESIGN.md §7):
//!
//! * the persistent pool's batched PP forward agrees row-for-row with the
//!   one-shot sharded forward (`pp_forward_once`) on the same weights,
//! * responses come back in strict id order with sane timestamps,
//! * TP serving is batching-invariant (outputs don't depend on how the
//!   micro-batcher grouped the queries), and
//! * the small-preset load run completes every query, with PP at or below
//!   TP's energy per 1k queries — recorded to BENCH_serve.json so CI keeps
//!   a serving perf trajectory per PR.

use std::path::PathBuf;

use phantom::config::{preset, Parallelism, ServeConfig};
use phantom::coordinator::driver::pp_forward_once;
use phantom::runtime::ExecServer;
use phantom::serve::{combined_records, run_load, LoadGenConfig, Server};
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;
use phantom::util::proptest::assert_close;

#[test]
fn pool_pp_forward_matches_one_shot_and_orders_responses() {
    let cfg = preset("quickstart", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let n = cfg.model.n;
    let queries = 24usize;

    let mut rng = Prng::new(0xCAFE);
    let rows: Vec<Tensor> = (0..queries).map(|_| Tensor::randn(&[n], 1.0, &mut rng)).collect();

    let scfg = ServeConfig {
        queue_depth: 32,
        max_batch: 8,
        linger_s: 1e-3,
        mode: Parallelism::Phantom,
    };
    let mut server = Server::start(&cfg, scfg, &exec).unwrap();
    for (i, row) in rows.iter().enumerate() {
        // spaced arrivals: several dispatches of varying size
        server.submit_blocking(1e-4 * (i + 1) as f64, row.clone()).unwrap();
    }
    let (responses, stats, per_rank) = server.finish().unwrap();
    assert_eq!(responses.len(), queries);
    assert!(stats.batches >= 3, "24 queries at max_batch 8 need >= 3 batches");

    // Reference: the one-shot sharded forward over the same weights.
    let mut flat = Vec::with_capacity(queries * n);
    for row in &rows {
        flat.extend_from_slice(row.data());
    }
    let x_full = Tensor::from_vec(&[queries, n], flat).unwrap();
    let want = pp_forward_once(&cfg, &exec, &x_full).unwrap();

    let mut prev_done = 0.0f64;
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "responses must arrive in admission order");
        assert!(r.arrival_s <= r.dispatch_s && r.dispatch_s < r.done_s);
        assert!(r.done_s >= prev_done, "batch completions must not regress");
        prev_done = r.done_s;
        assert!(r.batch_size >= 1 && r.batch_size <= scfg.max_batch);
        let want_row = &want.data()[i * n..(i + 1) * n];
        assert_close(r.y.data(), want_row, 1e-4, 1e-5)
            .unwrap_or_else(|e| panic!("query {i}: {e}"));
    }

    // Persistent ranks: one fabric, reused across every dispatch.
    assert_eq!(per_rank.len(), cfg.p);
    for rank in &per_rank {
        assert_eq!(
            rank.stats.all_gathers,
            stats.batches * cfg.model.layers as u64,
            "one All-Gather per layer per dispatched batch"
        );
    }
}

#[test]
fn tp_serving_is_batching_invariant() {
    let cfg = preset("quickstart", Parallelism::Tensor).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let n = cfg.model.n;
    let queries = 12usize;
    let mut rng = Prng::new(0xBEEF);
    let rows: Vec<Tensor> = (0..queries).map(|_| Tensor::randn(&[n], 1.0, &mut rng)).collect();

    let mut outputs: Vec<Vec<Tensor>> = Vec::new();
    for max_batch in [1usize, 6] {
        let scfg = ServeConfig {
            queue_depth: 2 * queries,
            max_batch,
            linger_s: 5e-4,
            mode: Parallelism::Tensor,
        };
        let mut server = Server::start(&cfg, scfg, &exec).unwrap();
        for (i, row) in rows.iter().enumerate() {
            server.submit_blocking(1e-5 * (i + 1) as f64, row.clone()).unwrap();
        }
        let (responses, _, _) = server.finish().unwrap();
        assert_eq!(responses.len(), queries);
        outputs.push(responses.into_iter().map(|r| r.y).collect());
    }
    for (i, (a, b)) in outputs[0].iter().zip(&outputs[1]).enumerate() {
        assert_close(a.data(), b.data(), 1e-4, 1e-5)
            .unwrap_or_else(|e| panic!("query {i} differs across batchings: {e}"));
    }
}

#[test]
fn live_metrics_latency_agrees_with_load_report_under_backpressure() {
    // Regression (latency accounting): the live `latency_s` histogram used
    // to be fed `done_s - arrival_s` with the *post-backpressure* admission
    // time, while the load report measured from the client's original
    // intent — so whenever submissions blocked, `Server::metrics()`
    // under-reported p50/p99 and the two surfaces disagreed. Saturate a
    // tiny queue so nearly every submission blocks, then assert the views
    // agree exactly (both use the same interpolating percentile over the
    // same client-intent samples).
    let cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let scfg = ServeConfig {
        queue_depth: 4,
        max_batch: 4,
        linger_s: 1e-4,
        mode: Parallelism::Phantom,
    };
    // Offered rate far beyond service capacity: the closed-loop stream
    // must block for queue slots almost immediately and stay blocked.
    let lcfg = LoadGenConfig { queries: 200, rate_qps: 1.0e6, seed: 0xBAC4, open_loop: false };
    let r = run_load(&cfg, &scfg, &lcfg, &exec).unwrap();

    assert_eq!(r.completed, 200, "blocking mode drops nothing");
    assert!(r.blocked > 0, "the run must actually exercise backpressure");

    let live_p50 = r.live.get("latency_s_p50").unwrap();
    let live_p99 = r.live.get("latency_s_p99").unwrap();
    assert_eq!(
        live_p50, r.latency.p50,
        "live latency p50 must equal the load report's (client-intent basis)"
    );
    assert_eq!(
        live_p99, r.latency.p99,
        "live latency p99 must equal the load report's (client-intent basis)"
    );
    assert_eq!(r.live.get("latency_s_count"), Some(r.completed as f64));

    // Queue wait is its own surface, and under heavy blocking the
    // client-intent latency strictly dominates the post-admission wait.
    let live_wait_p50 = r.live.get("queue_wait_s_p50").unwrap();
    assert_eq!(live_wait_p50, r.queue_wait.p50);
    assert!(
        r.latency.p50 > r.queue_wait.p50,
        "blocked intents must stretch latency beyond queue wait: latency p50 {} vs wait p50 {}",
        r.latency.p50,
        r.queue_wait.p50
    );
    assert_eq!(r.live.get("blocked"), Some(r.blocked as f64));
}

#[test]
fn small_preset_load_run_pp_beats_tp_energy_and_records_trajectory() {
    let queries = 256usize;
    let lcfg = LoadGenConfig { queries, rate_qps: 2_000.0, seed: 0x5E47E, open_loop: false };
    let mut reports = Vec::new();
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let cfg = preset("small", mode).unwrap();
        let exec = ExecServer::for_run(&cfg).unwrap();
        let scfg = ServeConfig { mode, ..ServeConfig::default() };
        let r = run_load(&cfg, &scfg, &lcfg, &exec).unwrap();
        assert_eq!(r.completed, queries, "{}: blocking backpressure drops nothing", mode.name());
        assert_eq!(r.misordered, 0, "{}: responses misordered", mode.name());
        assert_eq!(r.rejected, 0);
        assert!(r.latency.p50 > 0.0 && r.latency.p95 >= r.latency.p50);
        assert!(r.throughput_qps > 0.0);
        assert_eq!(r.queue_depth, scfg.queue_depth);
        reports.push(r);
    }
    let records = combined_records(&reports);
    let (pp, tp) = (reports[0].energy_per_kq_j, reports[1].energy_per_kq_j);
    assert!(
        pp <= tp,
        "PP must serve at no more energy than TP per 1k queries: pp={pp} tp={tp}"
    );
    // PP moves strictly fewer floats on the wire per query (Table II).
    assert!(
        reports[0].comm.floats_moved < reports[1].comm.floats_moved,
        "PP wire traffic {} must undercut TP's {}",
        reports[0].comm.floats_moved,
        reports[1].comm.floats_moved
    );

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json");
    let virtual_s = reports
        .iter()
        .flat_map(|r| r.per_rank.iter())
        .map(|pr| pr.ledger.end_s)
        .fold(0.0, f64::max);
    let meta = phantom::util::json::BenchMeta::new("serve", virtual_s);
    phantom::serve::write_records_json_with_meta(&path, &records, &meta).unwrap();
    eprintln!(
        "serve trajectory: pp {pp:.1} J/kq vs tp {tp:.1} J/kq -> {}",
        path.display()
    );
}
