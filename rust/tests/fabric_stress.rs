//! Property-style stress tests of the collective fabric: random sequences
//! of collectives over random shapes/rank-counts must match a
//! single-threaded reference, keep all virtual clocks aligned, and satisfy
//! the ledger identity busy + comm + idle == now for every rank.
//! (DESIGN.md §6 invariants 4 and 5.)

use std::sync::Arc;
use std::thread;

use phantom::comm::{Endpoint, Fabric};
use phantom::energy::{Activity, EnergyLedger};
use phantom::simnet::NetworkProfile;
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;
use phantom::util::proptest::assert_close;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Op {
    AllGather,
    ReduceScatter,
    AllReduce,
    Broadcast(usize),
    Barrier,
}

fn random_plan(rng: &mut Prng, p: usize) -> Vec<(Op, Vec<usize>, f64)> {
    let rounds = rng.int_in(3, 12) as usize;
    (0..rounds)
        .map(|_| {
            let shape = vec![rng.int_in(1, 5) as usize, rng.int_in(1, 6) as usize];
            let work = rng.next_f64() * 1e-3;
            let op = match rng.int_in(0, 4) {
                0 => Op::AllGather,
                1 => Op::ReduceScatter,
                2 => Op::AllReduce,
                3 => Op::Broadcast(rng.int_in(0, p as u64 - 1) as usize),
                _ => Op::Barrier,
            };
            (op, shape, work)
        })
        .collect()
}

/// Single-threaded reference of the whole plan: returns each rank's final
/// accumulated checksum.
fn reference(plan: &[(Op, Vec<usize>, f64)], p: usize, seed: u64) -> Vec<f64> {
    let mut acc = vec![0.0f64; p];
    for (round, (op, shape, _)) in plan.iter().enumerate() {
        // each rank's contribution tensor (same derivation as the threads)
        let inputs: Vec<Tensor> = (0..p)
            .map(|r| contribution(seed, round, r, shape, *op, p))
            .collect();
        match op {
            Op::AllGather => {
                let stacked = Tensor::stack(&inputs).unwrap();
                let sum: f64 = stacked.data().iter().map(|&x| x as f64).sum();
                for a in acc.iter_mut() {
                    *a += sum;
                }
            }
            Op::ReduceScatter => {
                for (j, a) in acc.iter_mut().enumerate() {
                    let mut slot = inputs[0].unstack_at(j);
                    for inp in &inputs[1..] {
                        slot.add_assign(&inp.unstack_at(j));
                    }
                    *a += slot.data().iter().map(|&x| x as f64).sum::<f64>();
                }
            }
            Op::AllReduce => {
                let mut total = inputs[0].clone();
                for inp in &inputs[1..] {
                    total.add_assign(inp);
                }
                let sum: f64 = total.data().iter().map(|&x| x as f64).sum();
                for a in acc.iter_mut() {
                    *a += sum;
                }
            }
            Op::Broadcast(root) => {
                let sum: f64 = inputs[*root].data().iter().map(|&x| x as f64).sum();
                for a in acc.iter_mut() {
                    *a += sum;
                }
            }
            Op::Barrier => {}
        }
    }
    acc
}

fn contribution(seed: u64, round: usize, rank: usize, shape: &[usize], op: Op, p: usize) -> Tensor {
    let mut rng = Prng::new(
        seed ^ (round as u64) << 32 ^ (rank as u64) << 8 ^ 0xFAB,
    );
    match op {
        // reduce_scatter needs leading dim p
        Op::ReduceScatter => {
            let mut s = vec![p];
            s.extend_from_slice(shape);
            Tensor::randn(&s, 1.0, &mut rng)
        }
        Op::Barrier => Tensor::zeros(&[0]),
        _ => Tensor::randn(shape, 1.0, &mut rng),
    }
}

fn run_plan(
    ep: &mut Endpoint,
    ledger: &mut EnergyLedger,
    plan: &[(Op, Vec<usize>, f64)],
    seed: u64,
    rank: usize,
    p: usize,
) -> f64 {
    let mut acc = 0.0f64;
    for (round, (op, shape, work)) in plan.iter().enumerate() {
        // unequal compute before the collective (exercises sync_to)
        ledger.advance(work * (rank + 1) as f64, Activity::Compute);
        let t = contribution(seed, round, rank, shape, *op, p);
        let out = match op {
            Op::AllGather => Some(ep.all_gather(t, ledger).unwrap()),
            Op::ReduceScatter => Some(ep.reduce_scatter(t, ledger).unwrap()),
            Op::AllReduce => Some(ep.all_reduce(t, ledger).unwrap()),
            Op::Broadcast(root) => Some(ep.broadcast(*root, t, ledger).unwrap()),
            Op::Barrier => {
                ep.barrier(ledger).unwrap();
                None
            }
        };
        if let Some(out) = out {
            acc += out.data().iter().map(|&x| x as f64).sum::<f64>();
        }
    }
    acc
}

#[test]
fn random_collective_sequences_match_reference() {
    let mut meta = Prng::new(0xFEED);
    for case in 0..25 {
        let p = meta.int_in(2, 6) as usize;
        let seed = meta.next_u64();
        let plan = Arc::new(random_plan(&mut meta, p));

        let endpoints = Fabric::new(p, NetworkProfile::frontier());
        let handles: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let plan = plan.clone();
                thread::spawn(move || {
                    let mut ledger = EnergyLedger::new();
                    let acc = run_plan(&mut ep, &mut ledger, &plan, seed, rank, p);
                    (acc, ledger)
                })
            })
            .collect();
        let results: Vec<(f64, EnergyLedger)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();

        // 1. payloads match the single-threaded reference
        let expect = reference(&plan, p, seed);
        for (rank, ((acc, _), want)) in results.iter().zip(&expect).enumerate() {
            assert_close(&[*acc as f32], &[*want as f32], 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("case {case} rank {rank}: {e}"));
        }

        // 2. synchronous collectives leave all clocks aligned
        let t0 = results[0].1.now_s;
        for (rank, (_, led)) in results.iter().enumerate() {
            assert!(
                (led.now_s - t0).abs() < 1e-12,
                "case {case} rank {rank}: clock skew {} vs {}",
                led.now_s,
                t0
            );
            // 3. ledger identity
            let total = led.busy_s() + led.comm_s() + led.idle_s();
            assert!(
                (total - led.now_s).abs() < 1e-9,
                "case {case} rank {rank}: ledger identity violated"
            );
        }
    }
}

#[test]
fn slowest_rank_never_idles_at_its_own_collective() {
    // The rank with the largest pre-collective compute arrives last; its
    // idle time for that round must be ~0.
    let p = 4;
    let endpoints = Fabric::new(p, NetworkProfile::frontier());
    let handles: Vec<_> = endpoints
        .into_iter()
        .enumerate()
        .map(|(rank, mut ep)| {
            thread::spawn(move || {
                let mut led = EnergyLedger::new();
                led.advance(0.010 * (rank + 1) as f64, Activity::Compute);
                ep.all_reduce(Tensor::filled(&[4], 1.0), &mut led).unwrap();
                (rank, led)
            })
        })
        .collect();
    for h in handles {
        let (rank, led) = h.join().unwrap();
        if rank == p - 1 {
            assert!(led.idle_s() < 1e-12, "slowest rank idled {}", led.idle_s());
        } else {
            let expected_idle = 0.010 * (p - rank - 1) as f64;
            assert!(
                (led.idle_s() - expected_idle).abs() < 1e-9,
                "rank {rank}: idle {} want {expected_idle}",
                led.idle_s()
            );
        }
    }
}
