//! Perf smoke for the native GEMM hot path, run under tier-1 (`cargo test`
//! builds with opt-level 2, see the workspace profile):
//!
//! * the blocked multithreaded `matmul` must agree with the naive
//!   reference at 512^3 and beat it by a wide margin, and
//! * the measured numbers are recorded to BENCH_native_backend.json at the
//!   repo root so every CI run leaves a perf trajectory point even when
//!   `cargo bench` never ran. (benches/microbench.rs refreshes the same
//!   file with the identical key schema.)
//!
//! The in-test assertion is deliberately conservative (>= 3x) so a loaded
//! CI box doesn't flake; the recorded speedup is the real number —
//! typically well above 5x, since the reference is the textbook i-j-k loop
//! with strided B access and the blocked kernel is packed, register-tiled
//! and row-band threaded.

use std::path::PathBuf;
use std::time::Instant;

use phantom::config::{preset, Parallelism};
use phantom::coordinator;
use phantom::runtime::ExecServer;
use phantom::tensor::Tensor;
use phantom::util::json::Json;
use phantom::util::prng::Prng;
use phantom::util::proptest::assert_close;

/// Minimum wall time of `runs` executions (min is the stablest estimator
/// under background load).
fn best_of<F: FnMut()>(runs: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

#[test]
fn blocked_matmul_beats_naive_and_records_trajectory() {
    let mut rng = Prng::new(1234);
    let mut records: Vec<(String, f64)> = Vec::new();
    let mut speedup_512 = 0.0;

    for (size, naive_runs, blocked_runs) in [(128usize, 5, 10), (512usize, 3, 6)] {
        let a = Tensor::randn(&[size, size], 1.0, &mut rng);
        let b = Tensor::randn(&[size, size], 1.0, &mut rng);

        // Correctness first: the fast path must match the oracle.
        let fast = a.matmul(&b).unwrap();
        let slow = a.matmul_naive(&b).unwrap();
        assert_close(fast.data(), slow.data(), 1e-3, 1e-4)
            .unwrap_or_else(|e| panic!("blocked != naive at {size}: {e}"));

        let t_naive = best_of(naive_runs, || {
            let _ = a.matmul_naive(&b).unwrap();
        });
        let t_blocked = best_of(blocked_runs, || {
            let _ = a.matmul(&b).unwrap();
        });
        let mut scratch = phantom::tensor::Scratch::new();
        let mut out = scratch.zeros(&[size, size]);
        let t_into = best_of(blocked_runs, || {
            a.matmul_into(&b, &mut out).unwrap();
        });
        let speedup = t_naive / t_blocked;
        eprintln!(
            "matmul {size}^3: naive {:.3}ms, blocked {:.3}ms, into {:.3}ms, speedup {speedup:.1}x",
            t_naive * 1e3,
            t_blocked * 1e3,
            t_into * 1e3
        );
        records.push((format!("naive_matmul_{size}_ns"), t_naive * 1e9));
        records.push((format!("blocked_matmul_{size}_ns"), t_blocked * 1e9));
        records.push((format!("matmul_into_{size}_ns"), t_into * 1e9));
        records.push((format!("speedup_blocked_over_naive_{size}"), speedup));
        if size == 512 {
            speedup_512 = speedup;
        }
    }

    // Full native PP iteration at p=4 (quickstart geometry), the end-to-end
    // trajectory number.
    const ITERS: usize = 5;
    let server = ExecServer::native();
    let mut cfg = preset("quickstart", Parallelism::Phantom).unwrap();
    cfg.train.max_iters = ITERS;
    let t_train = best_of(2, || {
        let _ = coordinator::train(&cfg, &server).unwrap();
    });
    records.push(("pp_iteration_p4_ns".to_string(), t_train / ITERS as f64 * 1e9));
    eprintln!("native PP iteration p=4: {:.3}ms", t_train / ITERS as f64 * 1e3);

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_native_backend.json");
    let obj = Json::obj(records.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect());
    std::fs::write(&path, obj.pretty()).expect("write BENCH_native_backend.json");

    assert!(
        speedup_512 >= 3.0,
        "blocked matmul only {speedup_512:.2}x over naive at 512^3 (want >= 3x \
         conservatively; >= 5x on an unloaded box)"
    );
}
