//! 1F1B schedule + ZeRO-1 sharded-state end-to-end suite (DESIGN.md §15):
//!
//! * the interleaved 1F1B schedule reproduces the synchronous schedule's
//!   loss trajectory BIT FOR BIT at every micro-batching, while exposing
//!   strictly less collective wire time on the energy ledger (the deferred
//!   boundary collectives drain under the next chunk's compute);
//! * micro = 1 is byte-identical to the historical non-pipelined loop for
//!   both schedules;
//! * ZeRO-1 sharded optimizer state matches the flat DP path and the
//!   single-thread oracle bitwise for dp in {2, 4} in both parallelism
//!   modes, holds ~1/dp of the moment floats per rank, and swaps the
//!   per-iteration DP All-Reduce for one Reduce-Scatter + one All-Gather;
//! * sharded and 1F1B checkpoints resume bit-identically mid-run, refuse
//!   schedule / sharding mismatches, and collapse_dp re-materializes the
//!   full optimizer state from the rank-ordered owned slices.

use phantom::ckpt::{collapse_dp, Snapshot};
use phantom::config::{
    CkptPolicy, HardwareConfig, ModelConfig, OptimizerConfig, Parallelism, RunConfig, Schedule,
    TrainConfig,
};
use phantom::coordinator::{self, TrainOptions, TrainReport};
use phantom::runtime::ExecServer;
use phantom::tensor::Tensor;
use phantom::testkit::ReferenceTrainer;
use phantom::util::prng::Prng;

/// A deep-enough pipeline for scheduling to matter: p = 4 stages, batch 8
/// so micro in {1, 2, 4} divides into whole chunks (and 3 exercises the
/// ragged 3+3+2 split).
fn pp_cfg(micro: usize, schedule: Schedule, iters: usize) -> RunConfig {
    RunConfig {
        mode: Parallelism::Phantom,
        p: 4,
        dp: 1,
        model: ModelConfig { n: 16, layers: 2, k: 2 },
        train: TrainConfig {
            batch: 8,
            optimizer: OptimizerConfig::Momentum { lr: 0.05, beta: 0.9 },
            seed: 0x1F1B_0001,
            max_iters: iters,
            target_loss: None,
            warmup_iters: 1,
            dataset_batches: 2,
            micro,
            schedule,
            ..TrainConfig::default()
        },
        hardware: HardwareConfig::frontier_measured(),
        artifact: Some("pipeline-case".to_string()),
        backend: Default::default(),
    }
}

/// The hybrid grid from hybrid_integration, parameterized on sharding:
/// p = 2 model ranks, batch 5 so dp = 2 and 4 split ragged rows.
fn dp_cfg(mode: Parallelism, dp: usize, sharded: bool, iters: usize) -> RunConfig {
    RunConfig {
        mode,
        p: 2,
        dp,
        model: ModelConfig { n: 12, layers: 2, k: 2 },
        train: TrainConfig {
            batch: 5,
            optimizer: OptimizerConfig::Adam { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            seed: 0x5EED_2E20,
            max_iters: iters,
            target_loss: None,
            warmup_iters: 1,
            dataset_batches: 2,
            sharded_state: sharded,
            ..TrainConfig::default()
        },
        hardware: HardwareConfig::frontier_measured(),
        artifact: Some("zero-case".to_string()),
        backend: Default::default(),
    }
}

fn train(cfg: &RunConfig) -> TrainReport {
    let server = ExecServer::for_run(cfg).expect("backend");
    coordinator::train(cfg, &server).expect("train")
}

fn bits(losses: &[f64]) -> Vec<u64> {
    losses.iter().map(|l| l.to_bits()).collect()
}

#[test]
fn one_f_one_b_matches_sync_bitwise_at_every_micro() {
    for micro in [1usize, 2, 3, 4] {
        let sync = train(&pp_cfg(micro, Schedule::Sync, 3));
        let ofob = train(&pp_cfg(micro, Schedule::OneFOneB, 3));
        assert_eq!(
            bits(&sync.losses),
            bits(&ofob.losses),
            "micro={micro}: 1f1b must replay the sync trajectory bitwise"
        );
        assert_eq!(sync.iterations, ofob.iterations);
    }
}

#[test]
fn micro_one_is_identical_to_the_flat_loop_for_both_schedules() {
    // micro = 1 short-circuits the chunking entirely, so both schedules
    // must reproduce the historical single-chunk loop exactly — including
    // its comm accounting (nothing in flight => nothing to defer).
    let flat = train(&pp_cfg(1, Schedule::Sync, 3));
    let ofob = train(&pp_cfg(1, Schedule::OneFOneB, 3));
    assert_eq!(bits(&flat.losses), bits(&ofob.losses));
    let comm = |r: &TrainReport| -> f64 { r.per_rank.iter().map(|pr| pr.ledger.comm_s).sum() };
    assert_eq!(comm(&flat), comm(&ofob), "micro=1 exposes every collective on both schedules");
}

#[test]
fn one_f_one_b_hides_boundary_collective_wire_time() {
    // Wire time is modeled (deterministic), so the comparison is exact:
    // with micro-batches in flight, 1F1B must expose strictly less
    // collective time than the synchronous schedule at the same math.
    let sync = train(&pp_cfg(4, Schedule::Sync, 3));
    let ofob = train(&pp_cfg(4, Schedule::OneFOneB, 3));
    let comm = |r: &TrainReport| -> f64 { r.per_rank.iter().map(|pr| pr.ledger.comm_s).sum() };
    let (cs, co) = (comm(&sync), comm(&ofob));
    assert!(co < cs, "1f1b exposed {co} s of comm, sync exposed {cs} s — deferral hid nothing");
    // The moved floats are identical — only the exposure changes.
    let floats =
        |r: &TrainReport| -> u64 { r.per_rank.iter().map(|pr| pr.stats.floats_moved).sum() };
    assert_eq!(floats(&sync), floats(&ofob));
}

#[test]
fn sharded_state_matches_flat_and_oracle_bitwise_all_dp() {
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        for dp in [2usize, 4] {
            let flat = train(&dp_cfg(mode, dp, false, 3));
            let sharded = train(&dp_cfg(mode, dp, true, 3));
            assert_eq!(
                bits(&flat.losses),
                bits(&sharded.losses),
                "{} dp={dp}: ZeRO-1 must be bit-identical to the flat DP path",
                mode.name()
            );

            let cfg = dp_cfg(mode, dp, true, 3);
            let mut oracle = ReferenceTrainer::new(&cfg).expect("oracle");
            oracle.run(3).expect("oracle run");
            assert_eq!(bits(&sharded.losses), bits(&oracle.losses), "{} dp={dp}", mode.name());
        }
    }
}

#[test]
fn sharded_state_holds_a_dp_fraction_of_the_moments_and_uses_rs_ag() {
    let iters = 3usize;
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let dp = 2usize;
        let flat = train(&dp_cfg(mode, dp, false, iters));
        let sharded = train(&dp_cfg(mode, dp, true, iters));

        let peak = |r: &TrainReport| -> usize {
            r.per_rank.iter().map(|pr| pr.opt_state_floats).max().unwrap_or(0)
        };
        let (pf, ps) = (peak(&flat), peak(&sharded));
        assert!(pf > 0, "{}: Adam must hold moments", mode.name());
        // Adam holds two moments; flat ranks hold both full (pf = 2*total),
        // sharded ranks hold the owned ceil(total/dp) slice of each.
        let slot = pf.div_ceil(2).div_ceil(dp);
        assert_eq!(ps, 2 * slot, "{}: sharded rank holds exactly its slice", mode.name());

        for r in &sharded.per_rank {
            assert_eq!(r.dp_stats.all_reduces, 0, "{}: ZeRO path must not all-reduce", mode.name());
            assert_eq!(r.dp_stats.reduce_scatters, iters as u64);
            assert_eq!(r.dp_stats.all_gathers, iters as u64);
        }
        for r in &flat.per_rank {
            assert_eq!(r.dp_stats.all_reduces, iters as u64);
            assert_eq!(r.dp_stats.reduce_scatters, 0);
            assert_eq!(r.dp_stats.all_gathers, 0);
        }
    }
}

#[test]
fn sharded_ckpt_resumes_bitwise_and_collapse_rebuilds_full_state() {
    let dir = std::env::temp_dir().join(format!("phantom-zero-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let cfg = dp_cfg(Parallelism::Phantom, 2, true, 4);
    let server = ExecServer::for_run(&cfg).expect("backend");
    let baseline = coordinator::train(&cfg, &server).expect("baseline").losses;

    let snap_run = coordinator::train_with(
        &cfg,
        &server,
        TrainOptions {
            ckpt: Some(CkptPolicy { every: 2, dir: dir.clone() }),
            ..Default::default()
        },
    )
    .expect("snapshotting run");
    assert_eq!(bits(&snap_run.losses), bits(&baseline));

    // Crash-equivalent: resume from the mid-run snapshot must replay the
    // tail bit-identically through the sharded optimizer slices.
    let snap = Snapshot::load(&dir.join("ckpt-000002")).expect("mid-run snapshot");
    assert!(snap.config.train.sharded_state);
    let resumed = coordinator::train_with(
        &cfg,
        &server,
        TrainOptions { resume: Some(snap.clone()), ..Default::default() },
    )
    .expect("resumed run")
    .losses;
    assert_eq!(bits(&resumed), bits(&baseline), "sharded resume must continue bit-identically");

    // A sharded snapshot refuses to resume a flat run: the state layout
    // shapes what each shard persists.
    let mut flat_cfg = cfg.clone();
    flat_cfg.train.sharded_state = false;
    let err = coordinator::train_with(
        &flat_cfg,
        &server,
        TrainOptions { resume: Some(snap.clone()), ..Default::default() },
    )
    .expect_err("sharding mismatch must be rejected");
    assert!(format!("{err:#}").contains("sharded_state"), "{err:#}");

    // collapse_dp re-materializes the full optimizer state by
    // concatenating the rank-ordered owned slices; the collapsed pure
    // snapshot serves replica 0's forward exactly.
    let final_snap = Snapshot::load(&dir.join("ckpt-000004")).expect("final snapshot");
    let pure = collapse_dp(&final_snap).expect("sharded collapse");
    assert_eq!(pure.config.dp, 1);
    let mut rng = Prng::new(0x2E20);
    let x = Tensor::randn(&[4, cfg.model.n], 1.0, &mut rng);
    let y_src = final_snap.forward_host(&x).unwrap();
    let y_pure = pure.forward_host(&x).unwrap();
    assert_eq!(y_src, y_pure);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn one_f_one_b_ckpt_resume_is_bit_identical() {
    let dir = std::env::temp_dir().join(format!("phantom-1f1b-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let cfg = pp_cfg(4, Schedule::OneFOneB, 4);
    let server = ExecServer::for_run(&cfg).expect("backend");
    let baseline = coordinator::train(&cfg, &server).expect("baseline").losses;
    coordinator::train_with(
        &cfg,
        &server,
        TrainOptions {
            ckpt: Some(CkptPolicy { every: 2, dir: dir.clone() }),
            ..Default::default()
        },
    )
    .expect("snapshotting run");

    let snap = Snapshot::load(&dir.join("ckpt-000002")).expect("mid-run snapshot");
    let resumed = coordinator::train_with(
        &cfg,
        &server,
        TrainOptions { resume: Some(snap.clone()), ..Default::default() },
    )
    .expect("resumed run")
    .losses;
    assert_eq!(bits(&resumed), bits(&baseline));

    // Resuming under a different micro-batching is refused — chunked row
    // splits change the f32 summation order, so the trajectory would
    // silently diverge from the snapshot's.
    let mut other = cfg.clone();
    other.train.micro = 2;
    let err = coordinator::train_with(
        &other,
        &server,
        TrainOptions { resume: Some(snap), ..Default::default() },
    )
    .expect_err("micro mismatch must be rejected");
    assert!(format!("{err:#}").contains("micro"), "{err:#}");

    std::fs::remove_dir_all(&dir).ok();
}
