//! Property tests pinning the SIMD/tuned GEMM engine against the naive
//! oracle — ragged and degenerate shapes, the whole tunable-parameter grid,
//! both ISAs where the machine has them — plus the band-workspace reuse
//! contract and a small differential sweep proving the tuned backend still
//! matches the single-rank oracle within the PR 4 conformance tolerances.

use phantom::tensor::gemm::{
    gemm_a_bt_acc_with, gemm_acc_with, gemm_at_b_acc_with, pack_pool_idle, PACK_POOL_CAP,
};
use phantom::tensor::seed::gemm_acc_seed;
use phantom::tensor::simd::{self, Isa};
use phantom::tensor::tune::GemmParams;
use phantom::tensor::Tensor;
use phantom::testkit::differential::{run_sweep, SweepConfig};
use phantom::util::prng::Prng;
use phantom::util::proptest::{assert_close, quickcheck};

/// The kernels the microkernel dispatcher must cover: every ISA compiled
/// into this binary that the machine can run.
fn isas() -> Vec<Isa> {
    simd::available()
}

/// Blocking-parameter grid hitting every dispatch path: both microkernel
/// heights, panel edges at/below the microkernel width, forced-serial and
/// forced-threaded.
fn param_grid() -> Vec<GemmParams> {
    let mut out = Vec::new();
    for &mr in &[4usize, 8] {
        for &kc in &[8usize, 64] {
            for &jc in &[8usize, 64] {
                for &pmf in &[0usize, usize::MAX] {
                    out.push(GemmParams { mr, kc, jc, max_bands: 3, par_min_flops: pmf });
                }
            }
        }
    }
    out
}

#[test]
fn degenerate_and_edge_shapes_match_naive() {
    // m < MR, n < lane width, k = 1, empty dims — the shapes where packing
    // and edge handling can silently go wrong.
    let shapes: &[(usize, usize, usize)] = &[
        (0, 3, 4),
        (3, 0, 4),
        (3, 4, 0),
        (0, 0, 0),
        (1, 1, 1),
        (1, 7, 1),
        (2, 1, 5), // k = 1
        (3, 5, 7), // everything below one tile
        (5, 9, 3), // n < lane width
        (7, 3, 8),
        (8, 8, 8),
        (9, 17, 33),
        (13, 1, 13),
    ];
    let mut rng = Prng::new(42);
    for &(m, k, n) in shapes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let want = a.matmul_naive(&b).unwrap();
        for isa in isas() {
            for p in param_grid() {
                let mut out = vec![0.5f32; m * n];
                let mut expect: Vec<f32> = want.data().iter().map(|x| x + 0.5).collect();
                gemm_acc_with(p, isa, a.data(), m, k, b.data(), n, &mut out);
                assert_close(&out, &expect, 1e-5, 1e-6).unwrap_or_else(|e| {
                    panic!("gemm ({m},{k},{n}) isa={isa:?} params={p:?}: {e}")
                });
                // Accumulation must stack: run again, expect doubled delta.
                gemm_acc_with(p, isa, a.data(), m, k, b.data(), n, &mut out);
                for (e, w) in expect.iter_mut().zip(want.data()) {
                    *e += w;
                }
                assert_close(&out, &expect, 1e-5, 1e-6).unwrap_or_else(|e| {
                    panic!("gemm acc x2 ({m},{k},{n}) isa={isa:?} params={p:?}: {e}")
                });
            }
        }
    }
}

#[test]
fn ragged_shapes_match_naive_all_params() {
    quickcheck("tuned gemm == naive over param grid", |rng| {
        let m = rng.int_in(1, 40) as usize;
        let k = rng.int_in(1, 40) as usize;
        let n = rng.int_in(1, 40) as usize;
        let a = Tensor::randn(&[m, k], 1.0, rng);
        let b = Tensor::randn(&[k, n], 1.0, rng);
        let want = a.matmul_naive(&b).unwrap();
        // One random param set per case keeps the property fast; the dense
        // grid runs in degenerate_and_edge_shapes_match_naive.
        let grid = param_grid();
        let p = grid[rng.int_in(0, grid.len() as u64 - 1) as usize];
        for isa in isas() {
            let mut out = vec![0.0f32; m * n];
            gemm_acc_with(p, isa, a.data(), m, k, b.data(), n, &mut out);
            assert_close(&out, want.data(), 1e-5, 1e-6)
                .map_err(|e| format!("({m},{k},{n}) isa={isa:?} params={p:?}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn transpose_families_match_naive_all_params() {
    quickcheck("tuned at_b/a_bt == naive", |rng| {
        let m = rng.int_in(1, 24) as usize;
        let k = rng.int_in(1, 24) as usize;
        let n = rng.int_in(1, 24) as usize;
        let grid = param_grid();
        let p = grid[rng.int_in(0, grid.len() as u64 - 1) as usize];

        // Aᵀ·B: A stored [k, m].
        let a = Tensor::randn(&[k, m], 1.0, rng);
        let b = Tensor::randn(&[k, n], 1.0, rng);
        let want = a.transpose().unwrap().matmul_naive(&b).unwrap();
        for isa in isas() {
            let mut out = vec![0.0f32; m * n];
            gemm_at_b_acc_with(p, isa, a.data(), k, m, b.data(), n, &mut out);
            assert_close(&out, want.data(), 1e-5, 1e-6)
                .map_err(|e| format!("at_b ({m},{k},{n}) isa={isa:?} params={p:?}: {e}"))?;
        }

        // A·Bᵀ: B stored [n, k].
        let c = Tensor::randn(&[m, k], 1.0, rng);
        let d = Tensor::randn(&[n, k], 1.0, rng);
        let want = c.matmul_naive(&d.transpose().unwrap()).unwrap();
        for isa in isas() {
            let mut out = vec![0.0f32; m * n];
            gemm_a_bt_acc_with(p, isa, c.data(), m, k, d.data(), n, &mut out);
            assert_close(&out, want.data(), 1e-5, 1e-6)
                .map_err(|e| format!("a_bt ({m},{k},{n}) isa={isa:?} params={p:?}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn portable_and_simd_isas_agree() {
    // Same packing, same accumulation structure — the two microkernel
    // families may differ only by FMA contraction, so they must agree to
    // tight tolerance on moderately sized products.
    let isas = isas();
    if isas.len() < 2 {
        eprintln!("portable_and_simd_isas_agree: only {isas:?} available, self-check only");
    }
    let mut rng = Prng::new(7);
    for (m, k, n) in [(33, 65, 47), (64, 64, 64), (5, 130, 9)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let p = GemmParams { mr: 8, kc: 32, jc: 32, max_bands: 2, par_min_flops: 0 };
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for &isa in &isas {
            let mut out = vec![0.0f32; m * n];
            gemm_acc_with(p, isa, a.data(), m, k, b.data(), n, &mut out);
            outs.push(out);
        }
        for o in &outs[1..] {
            assert_close(o, &outs[0], 1e-5, 1e-6)
                .unwrap_or_else(|e| panic!("ISA disagreement at ({m},{k},{n}): {e}"));
        }
    }
}

#[test]
fn seed_kernel_still_matches_naive() {
    // The frozen baseline itself must stay correct, or the regression gate
    // measures garbage.
    let mut rng = Prng::new(99);
    for (m, k, n) in [(1, 1, 1), (7, 13, 9), (64, 32, 48), (130, 70, 65)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let want = a.matmul_naive(&b).unwrap();
        let mut out = vec![0.0f32; m * n];
        gemm_acc_seed(a.data(), m, k, b.data(), n, &mut out);
        assert_close(&out, want.data(), 1e-5, 1e-6)
            .unwrap_or_else(|e| panic!("seed kernel ({m},{k},{n}): {e}"));
    }
}

#[test]
fn threaded_bands_return_workspace_to_pool() {
    // A forced-multithreaded GEMM must leave its per-band buffers in the
    // global pool (not dead thread-locals), and the pool must stay bounded.
    // Tests run concurrently and share the pool, so assertions are
    // one-sided: at least the band count after, never above the cap.
    let p = GemmParams { mr: 4, kc: 16, jc: 16, max_bands: 4, par_min_flops: 0 };
    let (m, k, n) = (64, 32, 32);
    let mut rng = Prng::new(123);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    for _ in 0..8 {
        let mut out = vec![0.0f32; m * n];
        gemm_acc_with(p, simd::active(), a.data(), m, k, b.data(), n, &mut out);
    }
    let idle = pack_pool_idle();
    assert!(idle >= 1, "threaded bands left no buffers in the pool");
    assert!(idle <= 64, "pool unbounded: {idle} idle buffers");

    // And matmul_into still reuses caller scratch unchanged.
    let mut scratch = phantom::tensor::Scratch::new();
    let mut out = scratch.zeros(&[m, n]);
    a.matmul_into(&b, &mut out).unwrap();
    assert_close(out.data(), a.matmul_naive(&b).unwrap().data(), 1e-4, 1e-5).unwrap();
    scratch.recycle(out);
    assert_eq!(scratch.pooled(), 1);
}

#[test]
fn pooled_tensor_churn_stays_within_band_pool_cap() {
    // Tensor::zeros_pooled / Tensor::recycle are the backward kernels'
    // scratch path: hammering the cycle far past the cap must never grow
    // the idle pool beyond PACK_POOL_CAP, and every pooled tensor must
    // come back zeroed (recycled buffers carry stale values).
    for _ in 0..3 * PACK_POOL_CAP {
        let mut t = Tensor::zeros_pooled(&[17, 3]);
        assert!(t.data().iter().all(|&v| v == 0.0), "pooled tensor not zeroed");
        t.data_mut().fill(7.5); // poison so a non-zeroing reuse would show
        t.recycle();
    }
    let idle = pack_pool_idle();
    assert!(idle <= PACK_POOL_CAP, "pool unbounded: {idle} idle buffers");
}

#[test]
fn backward_fused_kernel_outputs_recycle_deterministically() {
    // The backward fused kernels draw their output tensors from the
    // bounded band pool; the rank loops recycle them at death. Churning
    // one kernel through many recycle cycles must (a) keep the idle pool
    // within its cap and (b) reproduce the first call's results bitwise —
    // proving reused buffers never leak stale data into outputs.
    use phantom::runtime::native::run_entry;
    use phantom::runtime::ManifestConfig;
    let (p, bsz, k, m) = (3usize, 4usize, 2usize, 8usize);
    let geo = ManifestConfig::native("pool-test", p, p * m, k, bsz);
    let mut rng = Prng::new(0xBA4D);
    let delta = Tensor::randn(&[bsz, m], 1.0, &mut rng);
    let h_sum = Tensor::randn(&[bsz, k], 1.0, &mut rng);
    let l = Tensor::randn(&[m, m], 1.0, &mut rng);
    let c = Tensor::randn(&[m, k], 1.0, &mut rng);
    let z_prev = Tensor::randn(&[bsz, m], 1.0, &mut rng);
    let d_prev = Tensor::randn(&[p, k, m], 1.0, &mut rng);
    let inputs: [&Tensor; 6] = [&delta, &h_sum, &l, &c, &z_prev, &d_prev];

    let want = run_entry(&geo, "pp_bwd_step", &inputs).unwrap();
    for round in 0..100 {
        let out = run_entry(&geo, "pp_bwd_step", &inputs).unwrap();
        assert_eq!(out.len(), want.len());
        for (o, w) in out.iter().zip(&want) {
            assert!(
                o.shape() == w.shape() && o.data() == w.data(),
                "round {round}: pooled reuse changed the kernel output"
            );
        }
        for t in out {
            t.recycle();
        }
        let idle = pack_pool_idle();
        assert!(idle <= PACK_POOL_CAP, "round {round}: pool unbounded ({idle} idle)");
    }
}

#[test]
fn tuned_backend_matches_oracle_in_differential_sweep() {
    // The PR 4 conformance contract: distributed execution over the tuned
    // kernels must match the single-rank oracle (same kernels, same shapes
    // → bitwise in practice; loss_rtol only absorbs platform drift) and the
    // fused kernels must match naive math within the sweep tolerances.
    let sw = SweepConfig { cases: 6, iters: 2, seed: 0x6E44, ..Default::default() };
    let report = run_sweep(&sw).unwrap();
    assert!(
        report.max_loss_dev <= sw.loss_rtol,
        "distributed vs oracle loss deviation {} exceeds {}",
        report.max_loss_dev,
        sw.loss_rtol
    );
    assert!(
        report.max_grad_dev <= sw.grad_rtol,
        "fused vs naive grad deviation {} exceeds {}",
        report.max_grad_dev,
        sw.grad_rtol
    );
    assert!(
        report.max_forward_dev <= sw.forward_rtol,
        "TP vs PP forward deviation {} exceeds {}",
        report.max_forward_dev,
        sw.forward_rtol
    );
}
