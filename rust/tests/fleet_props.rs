//! Fleet router/autoscaler property tests (DESIGN.md §14):
//!
//! * deterministic replay: the same trace, seed and policy produce a
//!   bit-identical `FleetReport` (routing, autoscaling and energy are all
//!   pure functions of the inputs);
//! * no query is dropped or reordered across replica scale-up and drain —
//!   every offered query is either completed or shed at admission, and
//!   per-replica response ids stay strictly sequential;
//! * the live queue-depth gauge agrees with the server's admission
//!   accounting after every submission.

use phantom::config::{preset, Parallelism, ServeConfig};
use phantom::runtime::ExecServer;
use phantom::serve::{
    run_fleet, Admission, AutoscaleConfig, FleetConfig, RoutePolicy, Server,
};
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;

fn tiny_scfg() -> ServeConfig {
    ServeConfig { queue_depth: 4, max_batch: 4, linger_s: 1e-3, mode: Parallelism::Phantom }
}

/// Two-phase trace that forces both autoscaler directions regardless of
/// absolute service times: a near-simultaneous flood saturates the
/// bounded queues (occupancy 1.0 -> scale-up), then a sparse trickle with
/// one-second gaps lets everything drain (occupancy 0.0 -> scale-down).
fn two_phase_arrivals() -> Vec<f64> {
    let mut t = Vec::new();
    for i in 1..=120 {
        t.push(1e-7 * i as f64);
    }
    for i in 0..20 {
        t.push(10.0 + i as f64);
    }
    t
}

fn scale_cfg(policy: RoutePolicy) -> FleetConfig {
    FleetConfig {
        policy,
        autoscale: AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 2,
            high_water: 0.75,
            low_water: 0.15,
            patience: 2,
            cooldown_s: 1e-6,
        },
    }
}

#[test]
fn fleet_replays_deterministically_and_scales_both_ways() {
    let cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let arrivals = two_phase_arrivals();
    let fcfg = scale_cfg(RoutePolicy::EnergyAware);

    let a = run_fleet(&cfg, &tiny_scfg(), &fcfg, &arrivals, 0xD0D0, &exec).unwrap();
    let b = run_fleet(&cfg, &tiny_scfg(), &fcfg, &arrivals, 0xD0D0, &exec).unwrap();
    assert_eq!(a, b, "same trace + seed + policy must replay bit-identically");

    // The trace must actually have exercised both scale directions.
    assert!(a.scale_ups >= 1, "the flood phase must trigger a scale-up");
    assert!(a.scale_downs >= 1, "the trickle phase must trigger a drain");
    assert!(a.shed > 0, "the flood must overflow the bounded queues");
    assert_eq!(a.misordered, 0);
    assert_eq!(a.completed + a.shed, arrivals.len(), "every query completed or shed");
    assert_eq!(a.per_replica_completed.iter().sum::<usize>(), a.completed);
    assert!(a.energy_j > 0.0 && a.latency.p50 > 0.0);

    // A different payload seed still conserves queries (routing is
    // payload-independent, so admission counts match exactly).
    let c = run_fleet(&cfg, &tiny_scfg(), &fcfg, &arrivals, 0x0514, &exec).unwrap();
    assert_eq!(c.completed + c.shed, arrivals.len());
    assert_eq!((c.completed, c.shed), (a.completed, a.shed));
}

#[test]
fn no_policy_drops_or_reorders_across_scale_events() {
    let cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let arrivals = two_phase_arrivals();
    for policy in RoutePolicy::all() {
        let r = run_fleet(&cfg, &tiny_scfg(), &scale_cfg(policy), &arrivals, 0xFEED, &exec)
            .unwrap();
        assert_eq!(r.misordered, 0, "{}: responses reordered", policy.name());
        assert_eq!(
            r.completed + r.shed,
            arrivals.len(),
            "{}: queries dropped",
            policy.name()
        );
        assert!(r.scale_ups >= 1, "{}: no scale-up under the flood", policy.name());
    }
}

#[test]
fn queue_depth_gauge_matches_admission_accounting() {
    let cfg = preset("tiny_p2", Parallelism::Phantom).unwrap();
    let exec = ExecServer::for_run(&cfg).unwrap();
    let mut server = Server::start(&cfg, tiny_scfg(), &exec).unwrap();
    let n = server.n();
    let mut rng = Prng::new(0x9A6E);

    let mut admitted = 0u64;
    let mut shed = 0u64;
    for i in 1..=64u64 {
        // Tight spacing keeps the queue saturated so both admissions and
        // rejections occur.
        let t = 1e-7 * i as f64;
        match server.try_submit(t, Tensor::randn(&[n], 1.0, &mut rng)).unwrap() {
            Admission::Accepted(_) => admitted += 1,
            Admission::Rejected => shed += 1,
        }
        let m = server.metrics();
        assert_eq!(
            m.get("queue_depth"),
            Some(server.queued() as f64),
            "gauge must track the pending queue after every submission"
        );
        assert_eq!(m.get("admitted"), Some(admitted as f64));
        if shed > 0 {
            assert_eq!(m.get("shed"), Some(shed as f64));
        }
    }
    assert!(shed > 0, "the flood must shed on queue_depth 4");
    let (responses, stats, _) = server.finish().unwrap();
    assert_eq!(stats.admitted, admitted);
    assert_eq!(stats.rejected, shed);
    assert_eq!(responses.len() as u64, admitted);
}
