//! Hybrid DP×(TP|PP) end-to-end suite (ISSUE 5):
//!
//! * distributed hybrid training ≡ the single-thread reference oracle,
//!   loss for loss BIT FOR BIT, for dp ∈ {1, 2, 4} in both parallelism
//!   modes — including a batch % dp != 0 split;
//! * the energy ledger reports the DP gradient All-Reduce as its own
//!   bucket (DpComm), the four buckets partition virtual time, and dp = 1
//!   runs never touch the DP fabric (bucket and stats identically zero);
//! * hybrid checkpoint → resume is bit-identical, and a hybrid snapshot
//!   reshard collapses the verified replicas into a pure layout that is
//!   forward-equivalent;
//! * hybrid smoke numbers (energy split, DP traffic) are recorded to
//!   BENCH_hybrid.json at the repo root for the CI artifact.

use std::path::PathBuf;

use phantom::ckpt::{collapse_dp, reshard, Snapshot};
use phantom::config::{
    CkptPolicy, HardwareConfig, ModelConfig, OptimizerConfig, Parallelism, RunConfig,
    TrainConfig,
};
use phantom::coordinator::{self, TrainOptions, TrainReport};
use phantom::runtime::ExecServer;
use phantom::tensor::Tensor;
use phantom::testkit::ReferenceTrainer;
use phantom::util::prng::Prng;

/// A small hybrid-friendly config: n=12 over p=2 model ranks, batch 5 so
/// dp ∈ {2, 4} exercises the remainder row split (5 = 3+2 = 2+1+1+1).
fn base_cfg(mode: Parallelism, dp: usize, iters: usize) -> RunConfig {
    RunConfig {
        mode,
        p: 2,
        dp,
        model: ModelConfig { n: 12, layers: 2, k: 2 },
        train: TrainConfig {
            batch: 5,
            optimizer: OptimizerConfig::Momentum { lr: 0.05, beta: 0.9 },
            seed: 0x5EED_0005,
            max_iters: iters,
            target_loss: None,
            warmup_iters: 1,
            dataset_batches: 2,
            ..TrainConfig::default()
        },
        hardware: HardwareConfig::frontier_measured(),
        artifact: Some("hybrid-case".to_string()),
        backend: Default::default(),
    }
}

fn train(cfg: &RunConfig) -> TrainReport {
    let server = ExecServer::for_run(cfg).expect("backend");
    coordinator::train(cfg, &server).expect("train")
}

#[test]
fn hybrid_training_matches_the_oracle_bitwise_all_dp() {
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        for dp in [1usize, 2, 4] {
            let cfg = base_cfg(mode, dp, 3);
            let report = train(&cfg);
            assert_eq!(report.dp, dp);
            assert_eq!(report.per_rank.len(), cfg.p * dp, "one report per world rank");

            let mut oracle = ReferenceTrainer::new(&cfg).expect("oracle");
            oracle.run(3).expect("oracle run");
            assert_eq!(report.losses.len(), oracle.losses.len());
            for (i, (a, b)) in report.losses.iter().zip(&oracle.losses).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} dp={dp} iter {i}: distributed {a} vs oracle {b}",
                    mode.name()
                );
            }
        }
    }
}

#[test]
fn dp_gradient_allreduce_is_its_own_energy_bucket() {
    let iters = 3usize;
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        // dp = 1: the DP fabric is never touched — bucket and stats zero.
        let pure = train(&base_cfg(mode, 1, iters));
        for r in &pure.per_rank {
            assert_eq!(r.ledger.dp_comm_s, 0.0, "{}: dp=1 must not charge DpComm", mode.name());
            assert_eq!(r.dp_stats.collectives(), 0);
            assert_eq!(r.dp_stats.floats_moved, 0);
        }

        // dp = 2: one DP all-reduce per iteration on every world rank,
        // charged to the DpComm bucket; buckets partition the clock.
        let cfg = base_cfg(mode, 2, iters);
        let hybrid = train(&cfg);
        let m = cfg.model.n / cfg.p;
        // The flat gradient message: every parameter tensor, including the
        // frozen zero D slot PP ships (it is part of the flattened list).
        let msg = match mode {
            Parallelism::Phantom => (m * m + m * cfg.model.k + cfg.p * cfg.model.k * m + m)
                * cfg.model.layers,
            Parallelism::Tensor => (cfg.model.n * m + m) * cfg.model.layers,
        } as u64;
        for r in &hybrid.per_rank {
            assert!(r.ledger.dp_comm_s > 0.0, "{}: rank {} has no DpComm", mode.name(), r.rank);
            assert_eq!(r.dp_stats.all_reduces, iters as u64, "one DP sync per iteration");
            assert_eq!(r.dp_stats.floats_moved, iters as u64 * msg, "{}", mode.name());
            let l = &r.ledger;
            let bucket_sum = l.busy_s + l.comm_s + l.idle_s + l.dp_comm_s;
            assert!(
                (bucket_sum - l.end_s).abs() <= 1e-9 * l.end_s.max(1.0),
                "rank {}: buckets {bucket_sum} != clock {}",
                r.rank,
                l.end_s
            );
            // Model-parallel traffic stays in its own bucket.
            assert!(l.comm_s > 0.0);
        }
    }
}

#[test]
fn hybrid_ckpt_resume_is_bit_identical_and_reshard_collapses() {
    let dir = std::env::temp_dir().join(format!("phantom-hybrid-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let cfg = base_cfg(Parallelism::Phantom, 2, 4);
    let server = ExecServer::for_run(&cfg).expect("backend");
    let baseline = coordinator::train(&cfg, &server).expect("baseline").losses;

    // Periodic snapshots, then resume from the mid-run snapshot.
    let snap_run = coordinator::train_with(
        &cfg,
        &server,
        TrainOptions {
            ckpt: Some(CkptPolicy { every: 2, dir: dir.clone() }),
            ..Default::default()
        },
    )
    .expect("snapshotting run");
    assert_eq!(snap_run.losses, baseline, "snapshotting must not perturb the math");

    let snap = Snapshot::load(&dir.join("ckpt-000002")).expect("mid-run snapshot");
    assert_eq!(snap.config.dp, 2);
    assert_eq!(snap.shards.len(), cfg.p * 2, "one shard per world rank");
    let resumed = coordinator::train_with(
        &cfg,
        &server,
        TrainOptions { resume: Some(snap.clone()), ..Default::default() },
    )
    .expect("resumed run")
    .losses;
    assert_eq!(
        resumed.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        baseline.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "hybrid resume must continue bit-identically"
    );

    // Resuming into a different dp is refused (the layout shapes the math).
    let mut wrong = cfg.clone();
    wrong.dp = 1;
    let err = coordinator::train_with(
        &wrong,
        &server,
        TrainOptions { resume: Some(snap.clone()), ..Default::default() },
    )
    .expect_err("dp mismatch must be rejected");
    assert!(format!("{err:#}").contains("dp="), "{err:#}");

    // Trained DP replicas stayed weight-identical: collapse verifies them
    // bitwise; reshard to a pure TP layout stays forward-equivalent.
    let final_snap = Snapshot::load(&dir.join("ckpt-000004")).expect("final snapshot");
    let pure = collapse_dp(&final_snap).expect("replicas must be weight-identical");
    assert_eq!(pure.config.dp, 1);
    let as_tp = reshard(&final_snap, 1, Parallelism::Tensor).expect("hybrid -> dense TP");
    assert_eq!(as_tp.config.dp, 1);
    let mut rng = Prng::new(0xE0E0);
    let x = Tensor::randn(&[4, cfg.model.n], 1.0, &mut rng);
    let y_src = final_snap.forward_host(&x).unwrap();
    let y_pure = pure.forward_host(&x).unwrap();
    let y_tp = as_tp.forward_host(&x).unwrap();
    assert_eq!(y_src, y_pure, "collapse keeps replica 0's forward exactly");
    for (a, b) in y_src.data().iter().zip(y_tp.data()) {
        assert!(
            (a - b).abs() / (1e-4 + a.abs().max(b.abs())) < 1e-3,
            "reshard diverged: {a} vs {b}"
        );
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hybrid_crash_wakes_dp_peers_promptly() {
    use phantom::testkit::FaultPlan;

    // Crash world rank 1 (replica 0, model rank 1) mid-train on a dp=2
    // grid. Its model group is poisoned by the fault path; its DP group
    // {1, 3} must be woken by the driver's DP poison guard — the run has
    // to surface the structured injected-fault error in wall-clock
    // seconds, not ride out the 60 s rendezvous timeout.
    let cfg = base_cfg(Parallelism::Phantom, 2, 6);
    let server = ExecServer::for_run(&cfg).expect("backend");
    let plan = FaultPlan::crash_at_iter(1, 2, cfg.mode, cfg.model.layers);
    let t0 = std::time::Instant::now();
    let err = coordinator::train_with(
        &cfg,
        &server,
        TrainOptions { faults: Some(plan.injector_factory()), ..Default::default() },
    )
    .expect_err("the injected crash must surface as an error");
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "error lost the fault payload: {msg}");
    assert!(msg.contains("rank 1"), "error must name the world rank: {msg}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "DP peers must wake via poison, not the 60 s rendezvous timeout ({:?})",
        t0.elapsed()
    );
}

#[test]
fn serve_pool_hot_swaps_hybrid_snapshots_via_collapse() {
    use phantom::config::ServeConfig;
    use phantom::serve::RankPool;

    // The pool itself is model-parallel; a HYBRID snapshot hot-swapped
    // into it must be collapsed (replicas verified bitwise) and then serve
    // exactly like the equivalent pure dp=1 snapshot.
    let cfg = base_cfg(Parallelism::Phantom, 1, 2);
    let server = ExecServer::for_run(&cfg).expect("backend");
    let scfg = ServeConfig {
        max_batch: cfg.train.batch,
        queue_depth: 4 * cfg.train.batch,
        linger_s: 1e-3,
        mode: cfg.mode,
    };
    let mut hybrid_cfg = cfg.clone();
    hybrid_cfg.dp = 2;
    hybrid_cfg.train.seed ^= 0xA5; // distinguishable from the pool's init
    let hybrid_snap = Snapshot::init(&hybrid_cfg).expect("hybrid snapshot");
    let mut pure_cfg = hybrid_cfg.clone();
    pure_cfg.dp = 1;
    let pure_snap = Snapshot::init(&pure_cfg).expect("pure snapshot");

    let mut rng = Prng::new(0x5E11);
    let x = Tensor::randn(&[cfg.train.batch, cfg.model.n], 1.0, &mut rng);

    let mut pool = RankPool::start(&cfg, &scfg, &server).expect("pool");
    let (y_before, _) = pool.execute(pool.free_s(), &x).expect("pre-swap batch");
    pool.load_weights(&hybrid_snap).expect("hybrid hot swap");
    let (y_hybrid, _) = pool.execute(pool.free_s(), &x).expect("post-swap batch");
    pool.shutdown().expect("pool shutdown");

    let mut pool2 = RankPool::start(&cfg, &scfg, &server).expect("pool2");
    pool2.load_weights(&pure_snap).expect("pure hot swap");
    let (y_pure, _) = pool2.execute(pool2.free_s(), &x).expect("pure batch");
    pool2.shutdown().expect("pool2 shutdown");

    assert_ne!(y_before, y_hybrid, "the swap must be observable");
    assert_eq!(y_hybrid, y_pure, "hybrid swap must serve replica 0's weights exactly");
}

/// Hybrid smoke numbers for CI: DP×TP and DP×PP at dp=2 — final loss,
/// energy split including the DP bucket, and DP traffic. Written to
/// BENCH_hybrid.json at the repo root (uploaded as a CI artifact).
#[test]
fn bench_hybrid_records() {
    let mut records: Vec<(String, f64)> = Vec::new();
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let cfg = base_cfg(mode, 2, 4);
        let report = train(&cfg);
        let tag = mode.name();
        let busy: f64 = report.per_rank.iter().map(|r| r.ledger.busy_s).sum();
        let comm: f64 = report.per_rank.iter().map(|r| r.ledger.comm_s).sum();
        let dp_comm: f64 = report.per_rank.iter().map(|r| r.ledger.dp_comm_s).sum();
        let dp_floats: u64 = report.per_rank.iter().map(|r| r.dp_stats.floats_moved).sum();
        assert!(dp_comm > 0.0);
        records.push((format!("hybrid_{tag}_dp2_final_loss"), *report.losses.last().unwrap()));
        records.push((format!("hybrid_{tag}_dp2_energy_train_j"), report.energy_train_j));
        records.push((format!("hybrid_{tag}_dp2_busy_s"), busy));
        records.push((format!("hybrid_{tag}_dp2_comm_s"), comm));
        records.push((format!("hybrid_{tag}_dp2_dp_comm_s"), dp_comm));
        records.push((format!("hybrid_{tag}_dp2_dp_floats_moved"), dp_floats as f64));
        // DP sync share of all communication time: the Huber-style
        // first-order term this PR makes visible.
        records.push((
            format!("hybrid_{tag}_dp2_dp_share_of_comm"),
            dp_comm / (comm + dp_comm).max(1e-12),
        ));
    }
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hybrid.json");
    let meta = phantom::util::json::BenchMeta::new("hybrid", 0.0);
    phantom::serve::write_records_json_with_meta(&path, &records, &meta)
        .expect("write BENCH_hybrid.json");
}
