//! Fused-segment equivalence: the fused artifacts (pp_fwd_step,
//! pp_bwd_step, pp_loss_step, tp_bwd_step) must compute exactly what their
//! unfused compositions compute, through PJRT.

use phantom::runtime::{default_artifact_dir, ExecServer};
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;
use phantom::util::proptest::assert_close;

fn server_or_skip() -> Option<ExecServer> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return None;
    }
    Some(ExecServer::start(dir).expect("exec server"))
}

#[test]
fn pp_fwd_step_equals_composition() {
    let Some(server) = server_or_skip() else { return };
    let h = server.handle();
    let m = server.manifest.config("tiny").unwrap().clone();
    let mut rng = Prng::new(1);
    let z_loc = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let mut g_all = Tensor::randn(&[m.p, m.batch, m.k], 1.0, &mut rng);
    g_all.zero_slot(0);
    let d = Tensor::randn(&[m.p, m.k, m.np], 1.0, &mut rng);
    let b = Tensor::randn(&[m.np], 1.0, &mut rng);
    let l_next = Tensor::randn(&[m.np, m.np], 1.0, &mut rng);
    let c_next = Tensor::randn(&[m.np, m.k], 1.0, &mut rng);

    let fused = h
        .execute(
            "tiny",
            "pp_fwd_step",
            vec![z_loc.clone(), g_all.clone(), d.clone(), b.clone(), l_next.clone(), c_next.clone()],
        )
        .unwrap()
        .outputs;
    let comb = h
        .execute("tiny", "pp_fwd_combine", vec![z_loc, g_all, d, b])
        .unwrap()
        .outputs;
    let local = h
        .execute("tiny", "pp_fwd_local", vec![comb[0].clone(), l_next, c_next])
        .unwrap()
        .outputs;
    assert_close(fused[0].data(), comb[0].data(), 1e-6, 1e-6).unwrap(); // y_out
    assert_close(fused[1].data(), comb[1].data(), 1e-6, 1e-6).unwrap(); // z
    assert_close(fused[2].data(), local[0].data(), 1e-6, 1e-6).unwrap(); // z_loc_next
    assert_close(fused[3].data(), local[1].data(), 1e-6, 1e-6).unwrap(); // g_next
}

#[test]
fn pp_bwd_step_equals_composition() {
    let Some(server) = server_or_skip() else { return };
    let h = server.handle();
    let m = server.manifest.config("tiny").unwrap().clone();
    let mut rng = Prng::new(2);
    let delta = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let h_sum = Tensor::randn(&[m.batch, m.k], 1.0, &mut rng);
    let l = Tensor::randn(&[m.np, m.np], 1.0, &mut rng);
    let c = Tensor::randn(&[m.np, m.k], 1.0, &mut rng);
    let z_prev = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let d_prev = Tensor::randn(&[m.p, m.k, m.np], 1.0, &mut rng);

    let fused = h
        .execute(
            "tiny",
            "pp_bwd_step",
            vec![delta.clone(), h_sum.clone(), l.clone(), c.clone(), z_prev.clone(), d_prev.clone()],
        )
        .unwrap()
        .outputs;
    let comb = h
        .execute("tiny", "pp_bwd_combine", vec![delta, h_sum, l, c, z_prev])
        .unwrap()
        .outputs;
    let compress = h
        .execute("tiny", "pp_bwd_compress", vec![comb[0].clone(), d_prev])
        .unwrap()
        .outputs;
    assert_close(fused[0].data(), comb[0].data(), 1e-6, 1e-6).unwrap();
    assert_close(fused[1].data(), compress[0].data(), 1e-6, 1e-6).unwrap();
}

#[test]
fn pp_loss_step_equals_composition() {
    let Some(server) = server_or_skip() else { return };
    let h = server.handle();
    let m = server.manifest.config("tiny").unwrap().clone();
    let mut rng = Prng::new(3);
    let y = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let z = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let t = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let d = Tensor::randn(&[m.p, m.k, m.np], 1.0, &mut rng);

    let fused = h
        .execute("tiny", "pp_loss_step", vec![y.clone(), z.clone(), t.clone(), d.clone()])
        .unwrap()
        .outputs;
    let mse = h.execute("tiny", "mse_delta", vec![y, z, t]).unwrap().outputs;
    let compress = h
        .execute("tiny", "pp_bwd_compress", vec![mse[1].clone(), d])
        .unwrap()
        .outputs;
    assert_close(fused[0].data(), mse[0].data(), 1e-6, 1e-6).unwrap(); // loss
    assert_close(fused[1].data(), mse[1].data(), 1e-6, 1e-6).unwrap(); // delta
    assert_close(fused[2].data(), compress[0].data(), 1e-6, 1e-6).unwrap(); // h_out
}

#[test]
fn tp_bwd_step_equals_composition() {
    let Some(server) = server_or_skip() else { return };
    let h = server.handle();
    let m = server.manifest.config("tiny").unwrap().clone();
    let mut rng = Prng::new(4);
    let dy = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let z_prev = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let y_full = Tensor::randn(&[m.batch, m.n], 1.0, &mut rng);

    let fused = h
        .execute("tiny", "tp_bwd_step", vec![dy.clone(), z_prev.clone(), y_full.clone()])
        .unwrap()
        .outputs;
    let fin = h
        .execute("tiny", "tp_bwd_finish", vec![dy, z_prev])
        .unwrap()
        .outputs;
    let grads = h
        .execute("tiny", "tp_grads", vec![y_full, fin[0].clone()])
        .unwrap()
        .outputs;
    assert_close(fused[0].data(), fin[0].data(), 1e-6, 1e-6).unwrap();
    assert_close(fused[1].data(), grads[0].data(), 1e-6, 1e-6).unwrap();
    assert_close(fused[2].data(), grads[1].data(), 1e-6, 1e-6).unwrap();
}
