//! Fused-segment equivalence: the fused entry points (pp_fwd_step,
//! pp_bwd_step, pp_loss_step, tp_bwd_step) must compute exactly what their
//! unfused compositions compute, through the backend dispatch path.
//!
//! Property-tested over random ragged geometries (p, B, k, m) on the
//! native backend — the shapes deliberately do NOT match the registered
//! config geometry, which only supplies the baked-in loss scale, so the
//! kernels are exercised well off the preset grid.

use phantom::runtime::ExecServer;
use phantom::tensor::Tensor;
use phantom::util::prng::Prng;
use phantom::util::proptest::{assert_close, quickcheck};

/// Random ragged PP geometry: (p, batch, k, m).
fn geometry(rng: &mut Prng) -> (usize, usize, usize, usize) {
    (
        rng.int_in(2, 5) as usize,
        rng.int_in(1, 9) as usize,
        rng.int_in(1, 5) as usize,
        rng.int_in(2, 10) as usize,
    )
}

#[test]
fn pp_fwd_step_equals_composition() {
    let server = ExecServer::native();
    let h = server.handle();
    quickcheck("pp_fwd_step == combine + local", |rng| {
        let (p, bsz, k, m) = geometry(rng);
        let z_loc = Tensor::randn(&[bsz, m], 1.0, rng);
        let mut g_all = Tensor::randn(&[p, bsz, k], 1.0, rng);
        g_all.zero_slot(0);
        let d = Tensor::randn(&[p, k, m], 1.0, rng);
        let b = Tensor::randn(&[m], 1.0, rng);
        let l_next = Tensor::randn(&[m, m], 1.0, rng);
        let c_next = Tensor::randn(&[m, k], 1.0, rng);

        let fused = h
            .execute(
                "tiny",
                "pp_fwd_step",
                &[&z_loc, &g_all, &d, &b, &l_next, &c_next],
            )
            .map_err(|e| e.to_string())?
            .outputs;
        let comb = h
            .execute("tiny", "pp_fwd_combine", &[&z_loc, &g_all, &d, &b])
            .map_err(|e| e.to_string())?
            .outputs;
        let local = h
            .execute("tiny", "pp_fwd_local", &[&comb[0], &l_next, &c_next])
            .map_err(|e| e.to_string())?
            .outputs;
        assert_close(fused[0].data(), comb[0].data(), 1e-6, 1e-6)?; // y_out
        assert_close(fused[1].data(), comb[1].data(), 1e-6, 1e-6)?; // z
        assert_close(fused[2].data(), local[0].data(), 1e-6, 1e-6)?; // z_loc_next
        assert_close(fused[3].data(), local[1].data(), 1e-6, 1e-6) // g_next
    });
}

#[test]
fn pp_bwd_step_equals_composition() {
    let server = ExecServer::native();
    let h = server.handle();
    quickcheck("pp_bwd_step == combine + compress", |rng| {
        let (p, bsz, k, m) = geometry(rng);
        let delta = Tensor::randn(&[bsz, m], 1.0, rng);
        let h_sum = Tensor::randn(&[bsz, k], 1.0, rng);
        let l = Tensor::randn(&[m, m], 1.0, rng);
        let c = Tensor::randn(&[m, k], 1.0, rng);
        let z_prev = Tensor::randn(&[bsz, m], 1.0, rng);
        let d_prev = Tensor::randn(&[p, k, m], 1.0, rng);

        let fused = h
            .execute(
                "tiny",
                "pp_bwd_step",
                &[&delta, &h_sum, &l, &c, &z_prev, &d_prev],
            )
            .map_err(|e| e.to_string())?
            .outputs;
        let comb = h
            .execute("tiny", "pp_bwd_combine", &[&delta, &h_sum, &l, &c, &z_prev])
            .map_err(|e| e.to_string())?
            .outputs;
        let compress = h
            .execute("tiny", "pp_bwd_compress", &[&comb[0], &d_prev])
            .map_err(|e| e.to_string())?
            .outputs;
        assert_close(fused[0].data(), comb[0].data(), 1e-6, 1e-6)?;
        assert_close(fused[1].data(), compress[0].data(), 1e-6, 1e-6)
    });
}

#[test]
fn pp_loss_step_equals_composition() {
    let server = ExecServer::native();
    let h = server.handle();
    quickcheck("pp_loss_step == mse_delta + compress", |rng| {
        let (p, bsz, k, m) = geometry(rng);
        let y = Tensor::randn(&[bsz, m], 1.0, rng);
        let z = Tensor::randn(&[bsz, m], 1.0, rng);
        let t = Tensor::randn(&[bsz, m], 1.0, rng);
        let d = Tensor::randn(&[p, k, m], 1.0, rng);

        let fused = h
            .execute("tiny", "pp_loss_step", &[&y, &z, &t, &d])
            .map_err(|e| e.to_string())?
            .outputs;
        let mse = h
            .execute("tiny", "mse_delta", &[&y, &z, &t])
            .map_err(|e| e.to_string())?
            .outputs;
        let compress = h
            .execute("tiny", "pp_bwd_compress", &[&mse[1], &d])
            .map_err(|e| e.to_string())?
            .outputs;
        assert_close(fused[0].data(), mse[0].data(), 1e-6, 1e-6)?; // loss
        assert_close(fused[1].data(), mse[1].data(), 1e-6, 1e-6)?; // delta
        assert_close(fused[2].data(), compress[0].data(), 1e-6, 1e-6) // h_out
    });
}

#[test]
fn tp_bwd_step_equals_composition() {
    let server = ExecServer::native();
    let h = server.handle();
    quickcheck("tp_bwd_step == finish + grads", |rng| {
        let (p, bsz, _k, m) = geometry(rng);
        let n = p * m;
        let dy = Tensor::randn(&[bsz, m], 1.0, rng);
        let z_prev = Tensor::randn(&[bsz, m], 1.0, rng);
        let y_full = Tensor::randn(&[bsz, n], 1.0, rng);

        let fused = h
            .execute("tiny", "tp_bwd_step", &[&dy, &z_prev, &y_full])
            .map_err(|e| e.to_string())?
            .outputs;
        let fin = h
            .execute("tiny", "tp_bwd_finish", &[&dy, &z_prev])
            .map_err(|e| e.to_string())?
            .outputs;
        let grads = h
            .execute("tiny", "tp_grads", &[&y_full, &fin[0]])
            .map_err(|e| e.to_string())?
            .outputs;
        assert_close(fused[0].data(), fin[0].data(), 1e-6, 1e-6)?;
        assert_close(fused[1].data(), grads[0].data(), 1e-6, 1e-6)?;
        assert_close(fused[2].data(), grads[1].data(), 1e-6, 1e-6)
    });
}
