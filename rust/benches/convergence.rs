//! `cargo bench` target: the MEASURED paper artifacts — the fixed-loss
//! convergence sweep behind Fig 7a/7b/7c and Table I. Trains 9 real models
//! (TP and PP across p in {2,4,8} and k in {4..32}) to a common loss on the
//! simulated cluster. Runs on the self-contained native backend by default;
//! set PHANTOM_BENCH_BACKEND=xla (with the `xla` cargo feature and a built
//! artifact bundle) to run through PJRT instead.

use phantom::experiments::fig7::{convergence_sweep, fig7a, fig7b, fig7c, table1};
use phantom::runtime::{default_artifact_dir, ExecServer};

fn main() {
    let server = if std::env::var("PHANTOM_BENCH_BACKEND").as_deref() == Ok("xla") {
        match ExecServer::start(default_artifact_dir()) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("SKIP convergence bench: {e:#}");
                return;
            }
        }
    } else {
        ExecServer::native()
    };
    eprintln!(
        "running the fixed-loss convergence sweep (9 training runs, {} backend)...",
        server.backend_name()
    );
    let t0 = std::time::Instant::now();
    let sweep = match convergence_sweep(&server) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("convergence sweep failed: {e:#}");
            std::process::exit(1);
        }
    };
    eprintln!(
        "sweep done in {:.1}s real time; lambda = {:.6}",
        t0.elapsed().as_secs_f64(),
        sweep.target_loss
    );
    for f in [fig7a, fig7b, fig7c, table1] {
        match f(&sweep) {
            Ok(r) => print!("{}", r.render_markdown()),
            Err(e) => {
                eprintln!("render failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
