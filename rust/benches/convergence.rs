//! `cargo bench` target: the MEASURED paper artifacts — the fixed-loss
//! convergence sweep behind Fig 7a/7b/7c and Table I. Trains 9 real models
//! (TP and PP across p in {2,4,8} and k in {4..32}) to a common loss on the
//! simulated cluster via PJRT. Takes a few minutes.
//!
//! Skipped gracefully when artifacts are missing (`make artifacts`).

use phantom::experiments::fig7::{convergence_sweep, fig7a, fig7b, fig7c, table1};
use phantom::runtime::{default_artifact_dir, ExecServer};

fn main() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP convergence bench: no artifacts at {}", dir.display());
        return;
    }
    let server = match ExecServer::start(&dir) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP convergence bench: {e:#}");
            return;
        }
    };
    eprintln!("running the fixed-loss convergence sweep (9 training runs)...");
    let t0 = std::time::Instant::now();
    let sweep = match convergence_sweep(&server) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("convergence sweep failed: {e:#}");
            std::process::exit(1);
        }
    };
    eprintln!(
        "sweep done in {:.1}s real time; lambda = {:.6}",
        t0.elapsed().as_secs_f64(),
        sweep.target_loss
    );
    for f in [fig7a, fig7b, fig7c, table1] {
        match f(&sweep) {
            Ok(r) => print!("{}", r.render_markdown()),
            Err(e) => {
                eprintln!("render failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
