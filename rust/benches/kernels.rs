//! `cargo bench` target: the GEMM kernel pass in detail.
//!
//! Measures, per tracked shape: the naive oracle, the frozen seed kernel,
//! and the live tuned engine — plus the transpose families (`Aᵀ·B`,
//! `A·Bᵀ`), which the seed computed with naive loops and the engine now
//! routes through the same packed SIMD path. Emits BENCH_kernels.json at
//! the repo root with the same key schema as tests/kernel_gate.rs (the
//! tier-1 writer), so the perf trajectory exists whichever one ran last.

mod bench_util;

use std::path::PathBuf;

use bench_util::{write_records_json, Bench};
use phantom::tensor::seed::gemm_acc_seed;
use phantom::tensor::simd::{self, Isa};
use phantom::tensor::tune::{self, TRACKED_SHAPES};
use phantom::tensor::{gemm_a_bt_acc, gemm_acc, gemm_at_b_acc, Tensor};
use phantom::util::prng::Prng;

fn main() {
    let isa = simd::active();
    tune::ensure_loaded();
    eprintln!(
        "kernel bench: ISA {}, {} tuned shape classes",
        isa.name(),
        tune::installed_classes()
    );
    let mut records: Vec<(String, f64)> = Vec::new();
    let mut rng = Prng::new(0x6A7E);
    let mut geomean_seed_log = 0.0f64;
    let mut geomean_naive_log = 0.0f64;

    let mut b = Bench::new("GEMM kernels — naive vs seed vs tuned engine (per tracked shape)");
    for &(m, k, n) in TRACKED_SHAPES {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let x = Tensor::randn(&[k, n], 1.0, &mut rng);
        let shape = format!("{m}x{k}x{n}");
        let big = m * k * n >= 1 << 26;
        let (naive_iters, fast_iters) = if big { (2, 8) } else { (4, 16) };

        let naive = b.case(&format!("naive {shape}"), 1, naive_iters, || {
            let _ = a.matmul_naive(&x).unwrap();
        });
        let mut out = vec![0.0f32; m * n];
        let seed = b.case(&format!("seed {shape}"), 2, fast_iters, || {
            out.fill(0.0);
            gemm_acc_seed(a.data(), m, k, x.data(), n, &mut out);
        });
        let tuned = b.case(&format!("tuned {shape}"), 2, fast_iters, || {
            out.fill(0.0);
            gemm_acc(a.data(), m, k, x.data(), n, &mut out);
        });

        records.push((format!("gemm_naive_{shape}_ns"), naive.mean * 1e9));
        records.push((format!("gemm_seed_{shape}_ns"), seed.mean * 1e9));
        records.push((format!("gemm_{shape}_ns"), tuned.mean * 1e9));
        records.push((format!("speedup_vs_naive_{shape}"), naive.mean / tuned.mean));
        records.push((format!("speedup_vs_seed_{shape}"), seed.mean / tuned.mean));
        geomean_seed_log += (seed.mean / tuned.mean).ln();
        geomean_naive_log += (naive.mean / tuned.mean).ln();
    }
    b.finish();

    // Transpose families at a representative backward-pass shape: the seed
    // ran these as naive rank-1 / dot loops; the engine packs them.
    let (m, k, n) = (256, 256, 256);
    let mut b = Bench::new("GEMM transpose families — packed strided views");
    let at = Tensor::randn(&[k, m], 1.0, &mut rng); // Aᵀ·B operand, stored [k, m]
    let bt = Tensor::randn(&[n, k], 1.0, &mut rng); // A·Bᵀ operand, stored [n, k]
    let lhs = Tensor::randn(&[m, k], 1.0, &mut rng);
    let rhs = Tensor::randn(&[k, n], 1.0, &mut rng);
    let mut out = vec![0.0f32; m * n];
    let s = b.case(&format!("at_b {k}x{m} @ {k}x{n}"), 2, 16, || {
        out.fill(0.0);
        gemm_at_b_acc(at.data(), k, m, rhs.data(), n, &mut out);
    });
    records.push((format!("gemm_at_b_{m}x{k}x{n}_ns"), s.mean * 1e9));
    let s = b.case(&format!("a_bt {m}x{k} @ ({n}x{k})ᵀ"), 2, 16, || {
        out.fill(0.0);
        gemm_a_bt_acc(lhs.data(), m, k, bt.data(), n, &mut out);
    });
    records.push((format!("gemm_a_bt_{m}x{k}x{n}_ns"), s.mean * 1e9));
    b.finish();

    let geomean_seed = (geomean_seed_log / TRACKED_SHAPES.len() as f64).exp();
    let geomean_naive = (geomean_naive_log / TRACKED_SHAPES.len() as f64).exp();
    records.push(("geomean_speedup_vs_seed".to_string(), geomean_seed));
    records.push(("geomean_speedup_vs_naive".to_string(), geomean_naive));
    records.push(("isa_avx2".to_string(), if isa == Isa::Avx2Fma { 1.0 } else { 0.0 }));
    records.push(("tuned_classes".to_string(), tune::installed_classes() as f64));
    eprintln!("geomean speedup: {geomean_seed:.2}x vs seed, {geomean_naive:.2}x vs naive");

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_kernels.json");
    write_records_json(&path, &records, "kernels");
}
