//! Shared micro-benchmark harness (criterion substitute; the offline crate
//! set has no criterion). Provides warmup + repeated timing with
//! mean/std/p50 reporting through util::stats, plus machine-readable JSON
//! record emission for perf-trajectory files (BENCH_*.json).

use std::time::Instant;

use phantom::util::stats::{summarize, Summary};
use phantom::util::table::{fmt_secs, Table};

/// Time `f` for `iters` measured runs after `warmup` runs; returns Summary
/// of per-run seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// A bench-table accumulator.
pub struct Bench {
    table: Table,
}

impl Bench {
    pub fn new(title: &str) -> Bench {
        Bench {
            table: Table::new(title, &["case", "mean", "p50", "p95", "std", "runs"]),
        }
    }

    pub fn case<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) -> Summary {
        let s = time_it(warmup, iters, f);
        self.table.row(vec![
            name.to_string(),
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
            fmt_secs(s.std),
            s.n.to_string(),
        ]);
        eprintln!("  {name}: mean {}", fmt_secs(s.mean));
        s
    }

    pub fn finish(self) {
        print!("{}", self.table.markdown());
        println!();
    }
}

/// Write (key, value) records as a flat JSON object — the machine-readable
/// perf trajectory future PRs diff against. Delegates to the library's
/// serializer (util::json::write_records_json_with_meta) so the format has
/// one source, keeping bench ergonomics: a failed write warns, not aborts.
/// `scenario` lands in the BenchMeta provenance header; benches measure
/// real wall time, so the virtual duration is stamped as 0.
pub fn write_records_json(path: &std::path::Path, records: &[(String, f64)], scenario: &str) {
    let meta = phantom::util::json::BenchMeta::new(scenario, 0.0);
    match phantom::util::json::write_records_json_with_meta(path, records, &meta) {
        Ok(()) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
