//! `cargo bench` target: regenerate every MODELED paper artifact
//! (Fig 5a/5b/5c, Fig 6, Table III) and print the tables. The measured
//! artifacts (Fig 7a/b/c, Table I) live in benches/convergence.rs.

use phantom::experiments;

fn main() {
    for id in ["fig5a", "fig5b", "fig5c", "fig6", "table3"] {
        eprintln!("== {id} ==");
        match experiments::run(id, None) {
            Ok(r) => print!("{}", r.render_markdown()),
            Err(e) => {
                eprintln!("{id} failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
