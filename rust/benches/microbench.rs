//! `cargo bench` target: substrate micro-benchmarks for the §Perf pass —
//! the native GEMM kernels against the naive reference, the fused-backend
//! dispatch round-trip, collective fabric rendezvous, tensor reshuffles on
//! the critical path, and JSON/manifest parsing.
//!
//! Emits BENCH_native_backend.json (repo root): ns/op for blocked vs naive
//! matmul at 128/512, the blocked-over-naive speedup, and the full native
//! PP iteration wall time at p=4 — the perf trajectory future PRs diff
//! against. (tests/native_perf.rs writes the same file under tier-1 so the
//! numbers exist even when only `cargo test` ran.)

mod bench_util;

use std::path::PathBuf;
use std::sync::Arc;
use std::thread;

use bench_util::{write_records_json, Bench};
use phantom::comm::Fabric;
use phantom::config::{preset, Parallelism};
use phantom::coordinator;
use phantom::energy::EnergyLedger;
use phantom::runtime::ExecServer;
use phantom::simnet::NetworkProfile;
use phantom::tensor::{Scratch, Tensor};
use phantom::util::json::Json;
use phantom::util::prng::Prng;

fn bench_native_matmul(records: &mut Vec<(String, f64)>) {
    let mut rng = Prng::new(1);
    let mut b = Bench::new("Tensor microbench — blocked multithreaded matmul vs naive reference");
    for (size, warmup, iters) in [(128usize, 3, 30), (512usize, 2, 8)] {
        let x = Tensor::randn(&[size, size], 1.0, &mut rng);
        let y = Tensor::randn(&[size, size], 1.0, &mut rng);
        let naive = b.case(&format!("naive matmul {size}^3"), warmup.min(1), iters.min(5), || {
            let _ = x.matmul_naive(&y).unwrap();
        });
        let blocked = b.case(&format!("blocked matmul {size}^3"), warmup, iters, || {
            let _ = x.matmul(&y).unwrap();
        });
        let mut scratch = Scratch::new();
        let mut out = scratch.zeros(&[size, size]);
        let into = b.case(&format!("matmul_into {size}^3 (scratch reuse)"), warmup, iters, || {
            x.matmul_into(&y, &mut out).unwrap();
        });
        records.push((format!("naive_matmul_{size}_ns"), naive.mean * 1e9));
        records.push((format!("blocked_matmul_{size}_ns"), blocked.mean * 1e9));
        records.push((format!("matmul_into_{size}_ns"), into.mean * 1e9));
        records.push((format!("speedup_blocked_over_naive_{size}"), naive.mean / blocked.mean));
    }
    b.finish();
}

fn bench_pp_iteration(records: &mut Vec<(String, f64)>) {
    // Full native PP training iterations at p=4 (quickstart geometry:
    // n=256, batch=16, L=2): rank threads + fused kernels + fabric.
    const ITERS_PER_RUN: usize = 5;
    let server = ExecServer::native();
    let mut cfg = preset("quickstart", Parallelism::Phantom).expect("preset");
    cfg.train.max_iters = ITERS_PER_RUN;
    let mut b = Bench::new("Native backend — full PP iteration (p=4, n=256, real threads)");
    let s = b.case(&format!("pp train {ITERS_PER_RUN} iters p=4"), 1, 5, || {
        let _ = coordinator::train(&cfg, &server).unwrap();
    });
    records.push((
        "pp_iteration_p4_ns".to_string(),
        s.mean / ITERS_PER_RUN as f64 * 1e9,
    ));
    b.finish();
}

fn bench_backend_dispatch() {
    // Native dispatch round-trip at tiny shapes: measures the per-call
    // overhead (manifest lookup + gate + shape checks) around the kernels.
    let server = ExecServer::native();
    let handle = server.handle();
    let m = server.manifest.config("tiny").unwrap().clone();
    let mut rng = Prng::new(2);
    let y = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let l = Tensor::randn(&[m.np, m.np], 1.0, &mut rng);
    let c = Tensor::randn(&[m.np, m.k], 1.0, &mut rng);
    let mut b = Bench::new("Runtime microbench — native execute round-trip (tiny shapes)");
    b.case("pp_fwd_local tiny (dispatch+kernel)", 5, 100, || {
        let _ = handle.execute("tiny", "pp_fwd_local", &[&y, &l, &c]).unwrap();
    });
    b.finish();
}

fn bench_collectives() {
    let mut b = Bench::new("L3 microbench — collective fabric (real thread rendezvous)");
    for (p, floats) in [(4usize, 512usize), (8, 512), (8, 16_384)] {
        b.case(&format!("all_gather p={p} m={floats}"), 3, 30, || {
            let eps = Fabric::new(p, NetworkProfile::frontier());
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    thread::spawn(move || {
                        let mut led = EnergyLedger::new();
                        for _ in 0..8 {
                            ep.all_gather(Tensor::zeros(&[floats]), &mut led).unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
    b.finish();
}

fn bench_tensor_ops() {
    let mut rng = Prng::new(1);
    let stacked = Tensor::randn(&[8, 32, 256], 1.0, &mut rng);
    let wide = Tensor::randn(&[32, 2048], 1.0, &mut rng);
    let mut b = Bench::new("L3 microbench — tensor reshuffles on the iteration path");
    b.case("concat_shards_stacked [8,32,256]", 10, 200, || {
        let _ = stacked.concat_shards_stacked().unwrap();
    });
    b.case("col_shards p=8 [32,2048]", 10, 200, || {
        let _ = wide.col_shards(8).unwrap();
    });
    b.case("col_slice [32,2048]->256", 10, 200, || {
        let _ = wide.col_slice(256, 256).unwrap();
    });
    let tall = Tensor::randn(&[2048, 512], 1.0, &mut rng);
    b.case("blocked transpose [2048,512]", 5, 50, || {
        let _ = tall.transpose().unwrap();
    });
    b.finish();
}

fn bench_json() {
    // Synthetic manifest-scale blob (artifact bundles are optional now).
    let rows: Vec<Json> = (0..200)
        .map(|i| {
            Json::obj(vec![
                ("name", Json::str(format!("cfg{i}"))),
                ("p", Json::int(8)),
                ("vals", Json::arr((0..20).map(Json::int).collect())),
            ])
        })
        .collect();
    let text = Arc::new(Json::arr(rows).pretty());
    let t2 = text.clone();
    let mut b = Bench::new("Util microbench — JSON parse (manifest-scale)");
    b.case(&format!("parse {} bytes", text.len()), 10, 200, move || {
        let _ = Json::parse(&t2).unwrap();
    });
    b.finish();
}

/// BENCH_native_backend.json lands at the repository root regardless of
/// the cargo invocation directory.
fn bench_json_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_native_backend.json")
}

fn main() {
    let mut records: Vec<(String, f64)> = Vec::new();
    bench_native_matmul(&mut records);
    bench_pp_iteration(&mut records);
    bench_backend_dispatch();
    bench_collectives();
    bench_tensor_ops();
    bench_json();
    write_records_json(&bench_json_path(), &records, "microbench");
}
