//! `cargo bench` target: substrate micro-benchmarks for the §Perf pass —
//! the L3 hot paths: collective fabric round-trips, tensor reshuffles on
//! the critical path, PJRT call overhead, and JSON/manifest parsing.

mod bench_util;

use std::sync::Arc;
use std::thread;

use bench_util::Bench;
use phantom::comm::Fabric;
use phantom::energy::EnergyLedger;
use phantom::runtime::{default_artifact_dir, ExecServer};
use phantom::simnet::NetworkProfile;
use phantom::tensor::Tensor;
use phantom::util::json::Json;
use phantom::util::prng::Prng;

fn bench_collectives() {
    let mut b = Bench::new("L3 microbench — collective fabric (real thread rendezvous)");
    for (p, floats) in [(4usize, 512usize), (8, 512), (8, 16_384)] {
        b.case(&format!("all_gather p={p} m={floats}"), 3, 30, || {
            let eps = Fabric::new(p, NetworkProfile::frontier());
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    thread::spawn(move || {
                        let mut led = EnergyLedger::new();
                        for _ in 0..8 {
                            ep.all_gather(Tensor::zeros(&[floats]), &mut led).unwrap();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
    b.finish();
}

fn bench_tensor_ops() {
    let mut rng = Prng::new(1);
    let stacked = Tensor::randn(&[8, 32, 256], 1.0, &mut rng);
    let wide = Tensor::randn(&[32, 2048], 1.0, &mut rng);
    let mut b = Bench::new("L3 microbench — tensor reshuffles on the iteration path");
    b.case("concat_shards_stacked [8,32,256]", 10, 200, || {
        let _ = stacked.concat_shards_stacked().unwrap();
    });
    b.case("col_shards p=8 [32,2048]", 10, 200, || {
        let _ = wide.col_shards(8).unwrap();
    });
    b.case("col_slice [32,2048]->256", 10, 200, || {
        let _ = wide.col_slice(256, 256).unwrap();
    });
    let a = Tensor::randn(&[128, 128], 1.0, &mut rng);
    let c = Tensor::randn(&[128, 128], 1.0, &mut rng);
    b.case("reference matmul 128^3", 5, 50, || {
        let _ = a.matmul(&c).unwrap();
    });
    b.finish();
}

fn bench_pjrt() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP pjrt microbench: no artifacts");
        return;
    }
    let server = ExecServer::start(&dir).expect("server");
    let handle = server.handle();
    let m = server.manifest.config("tiny").unwrap().clone();
    let mut rng = Prng::new(2);
    let y = Tensor::randn(&[m.batch, m.np], 1.0, &mut rng);
    let l = Tensor::randn(&[m.np, m.np], 1.0, &mut rng);
    let c = Tensor::randn(&[m.np, m.k], 1.0, &mut rng);
    let mut b = Bench::new("Runtime microbench — PJRT execute round-trip (tiny shapes)");
    b.case("pp_fwd_local tiny (exec+transfer)", 5, 100, || {
        let _ = handle
            .execute("tiny", "pp_fwd_local", vec![y.clone(), l.clone(), c.clone()])
            .unwrap();
    });
    b.finish();
}

fn bench_json() {
    let manifest_path = default_artifact_dir().join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).unwrap_or_else(|_| {
        // fall back to a synthetic blob
        let rows: Vec<Json> = (0..200)
            .map(|i| {
                Json::obj(vec![
                    ("name", Json::str(format!("cfg{i}"))),
                    ("p", Json::int(8)),
                    ("vals", Json::arr((0..20).map(Json::int).collect())),
                ])
            })
            .collect();
        Json::arr(rows).pretty()
    });
    let mut b = Bench::new("Util microbench — JSON parse (manifest-scale)");
    let text = Arc::new(text);
    let t2 = text.clone();
    b.case(&format!("parse {} bytes", text.len()), 10, 200, move || {
        let _ = Json::parse(&t2).unwrap();
    });
    b.finish();
}

fn main() {
    bench_collectives();
    bench_tensor_ops();
    bench_pjrt();
    bench_json();
}
