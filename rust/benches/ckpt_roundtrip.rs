//! Checkpoint throughput bench: save/load/reshard a larger snapshot than
//! the CI smoke test and refresh BENCH_ckpt.json with higher-confidence
//! numbers.
//!
//! Run with:  cargo bench --bench ckpt_roundtrip [n] [p]

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;
use phantom::ckpt::{reshard, Snapshot};
use phantom::config::{preset, ModelConfig, Parallelism};
use phantom::util::json::{write_records_json_with_meta, BenchMeta};
use phantom::util::table::Table;

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let p: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let mut cfg = preset("tiny", Parallelism::Phantom)?;
    cfg.p = p;
    cfg.model = ModelConfig { n, layers: 2, k: (n / p / 4).max(1) };
    cfg.artifact = Some("ckpt_bench".to_string());
    let snap = Snapshot::init(&cfg)?;

    let dir = std::env::temp_dir().join(format!("phantom-ckpt-bench-{}", std::process::id()));

    let t0 = Instant::now();
    snap.save(&dir)?;
    let save_s = t0.elapsed().as_secs_f64();

    let bytes: u64 = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.metadata().map(|m| m.len()).unwrap_or(0))
        .sum();
    let mb = bytes as f64 / 1e6;

    let t0 = Instant::now();
    let loaded = Snapshot::load(&dir)?;
    let load_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let merged = reshard(&loaded, p / 2, Parallelism::Phantom)?;
    let reshard_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let tp = reshard(&loaded, p, Parallelism::Tensor)?;
    let convert_s = t0.elapsed().as_secs_f64();

    std::fs::remove_dir_all(&dir).ok();

    let mut table = Table::new(
        &format!("Checkpoint bench — PP p={p}, n={n} ({mb:.2} MB on disk)"),
        &["op", "seconds", "MB/s"],
    );
    table.row(vec!["save".into(), format!("{save_s:.4}"), format!("{:.0}", mb / save_s)]);
    table.row(vec!["load".into(), format!("{load_s:.4}"), format!("{:.0}", mb / load_s)]);
    table.row(vec![
        format!("reshard pp p={p} -> p={}", merged.p()),
        format!("{reshard_s:.4}"),
        "-".into(),
    ]);
    table.row(vec![
        format!("convert pp -> tp p={}", tp.p()),
        format!("{convert_s:.4}"),
        "-".into(),
    ]);
    print!("{}", table.markdown());

    let records = vec![
        ("snapshot_mb".to_string(), mb),
        ("save_s".to_string(), save_s),
        ("load_s".to_string(), load_s),
        (format!("reshard_p{p}_to_p{}_s", p / 2), reshard_s),
        ("convert_pp_to_tp_s".to_string(), convert_s),
        ("save_mb_per_s".to_string(), mb / save_s.max(1e-9)),
        ("load_mb_per_s".to_string(), mb / load_s.max(1e-9)),
    ];
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_ckpt.json");
    write_records_json_with_meta(&path, &records, &BenchMeta::new("ckpt", 0.0))?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
