//! Serving latency/energy bench: a longer load-generator run than the CI
//! smoke test, refreshing BENCH_serve.json with higher-confidence numbers.
//!
//! Run with:  cargo bench --bench serve_latency [queries] [rate_qps]

use std::path::PathBuf;

use anyhow::Result;
use phantom::config::{preset, Parallelism, ServeConfig};
use phantom::runtime::ExecServer;
use phantom::serve::{combined_records, run_load, LoadGenConfig};
use phantom::util::table::{fmt_joules, fmt_secs, Table};

fn main() -> Result<()> {
    let queries: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1024);
    let rate_qps: f64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(2_000.0);

    let mut table = Table::new(
        &format!("Serving bench — small preset, {queries} queries @ {rate_qps} q/s"),
        &["mode", "p50", "p95", "throughput (q/s)", "energy / 1k queries", "mean batch"],
    );
    let mut reports = Vec::new();
    for mode in [Parallelism::Phantom, Parallelism::Tensor] {
        let cfg = preset("small", mode)?;
        let exec = ExecServer::for_run(&cfg)?;
        let scfg = ServeConfig { mode, ..ServeConfig::default() };
        let lcfg = LoadGenConfig { queries, rate_qps, ..LoadGenConfig::default() };
        eprintln!("serving {} ...", mode.name());
        let r = run_load(&cfg, &scfg, &lcfg, &exec)?;
        assert_eq!(r.misordered, 0);
        assert_eq!(r.completed, queries);
        table.row(vec![
            mode.name().to_uppercase(),
            fmt_secs(r.latency.p50),
            fmt_secs(r.latency.p95),
            format!("{:.0}", r.throughput_qps),
            fmt_joules(r.energy_per_kq_j),
            format!("{:.1}", r.mean_batch),
        ]);
        reports.push(r);
    }
    print!("{}", table.markdown());
    let records = combined_records(&reports);

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json");
    let virtual_s = reports
        .iter()
        .flat_map(|r| r.per_rank.iter())
        .map(|pr| pr.ledger.end_s)
        .fold(0.0, f64::max);
    let meta = phantom::util::json::BenchMeta::new("serve", virtual_s);
    phantom::serve::write_records_json_with_meta(&path, &records, &meta)?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
