"""L2: per-rank step functions for phantom-parallel and tensor-parallel FFNs.

Each function here is one *collective-free* segment of a training iteration:
the Rust coordinator executes these via PJRT and runs the collectives
(All-Gather / Reduce-Scatter / All-Reduce / Broadcast) between them. The
segment boundaries are exactly where the paper's Algorithm 1 places the
custom autograd communication.

Entry points lowered by aot.py (shapes are static per artifact config):

  phantom parallelism (paper Sec. IV):
    pp_fwd_local     (y, L, C)                         -> (z_loc, g)
    pp_fwd_combine   (z_loc, g_all, D, b)              -> (y_out, z)
    pp_bwd_compress  (delta, D)                        -> h_out [p,B,k]
    pp_bwd_combine   (delta_next, h_sum, L, C, z_prev) -> delta_prev
    pp_grads         (y_prev, delta, h_sum, g_all)     -> (dL, dC, dD, db)

  tensor parallelism (paper Sec. II-B / Table II):
    tp_fwd           (y_full, W, b)                    -> (y_out, z)
    tp_bwd_partial   (delta, W)                        -> dy_full
    tp_bwd_finish    (dy_shard, z_prev)                -> delta_prev
    tp_grads         (y_full, delta)                   -> (dW, db)

  shared:
    make_mse_delta(scale) -> (y_out, z, target)        -> (loss_local, delta_L)

Set ``use_pallas(True)`` to route the forward/backward hot-spots through the
L1 Pallas kernels (kernels/phantom.py, kernels/tp.py); the default jnp path
(kernels/ref.py) lowers to identical math that XLA fuses to plain dots.
aot.py emits both variants; pytest asserts they agree.
"""

from __future__ import annotations

from .kernels import phantom as pk
from .kernels import ref
from .kernels import tp as tpk

_USE_PALLAS = False


def use_pallas(flag: bool) -> None:
    """Route hot-spot ops through the Pallas kernels (interpret mode)."""
    global _USE_PALLAS
    _USE_PALLAS = bool(flag)


# ---------------------------------------------------------------------------
# Phantom parallelism
# ---------------------------------------------------------------------------

def pp_fwd_local(y, L, C):
    if _USE_PALLAS:
        z_loc, g = pk.fused_local_compress(y, L, C)
        return z_loc, g
    return ref.pp_fwd_local(y, L, C)


def pp_fwd_combine(z_loc, g_all, D, b):
    if _USE_PALLAS:
        z = pk.decompress_accum(z_loc, g_all, D, b)
        return ref.relu(z), z
    return ref.pp_fwd_combine(z_loc, g_all, D, b)


def pp_bwd_compress(delta, D):
    if _USE_PALLAS:
        return pk.error_compress(delta, D)
    return ref.pp_bwd_compress(delta, D)


def pp_bwd_combine(delta_next, h_sum, L, C, z_prev):
    return ref.pp_bwd_combine(delta_next, h_sum, L, C, z_prev)


def pp_grads(y_prev, delta, h_sum, g_all):
    return ref.pp_grads(y_prev, delta, h_sum, g_all)


# ---------------------------------------------------------------------------
# Tensor parallelism
# ---------------------------------------------------------------------------

def tp_fwd(y_full, W, b):
    if _USE_PALLAS:
        z = tpk.tp_shard_matmul(y_full, W, b)
        return ref.relu(z), z
    return ref.tp_fwd(y_full, W, b)


def tp_bwd_partial(delta, W):
    return ref.tp_bwd_partial(delta, W)


def tp_bwd_finish(dy_shard, z_prev):
    return ref.tp_bwd_finish(dy_shard, z_prev)


def tp_grads(y_full, delta):
    return ref.tp_grads(y_full, delta)


# ---------------------------------------------------------------------------
# Fused step entries (performance pass; see EXPERIMENTS.md §Perf)
#
# Adjacent collective-free segments of the schedule are fused into single
# executables to cut PJRT call overhead: PP from 10 to 7 calls per
# 2-layer iteration, TP from 7 to 6. Numerics are identical (pytest
# asserts fused == unfused); the collective schedule is unchanged.
# ---------------------------------------------------------------------------

def pp_fwd_step(z_loc, g_all, D, b, L_next, C_next):
    """fwd_combine(l) fused with fwd_local(l+1) — the inter-collective
    segment between two forward All-Gathers."""
    y_out, z = pp_fwd_combine(z_loc, g_all, D, b)
    z_loc_next, g_next = pp_fwd_local(y_out, L_next, C_next)
    return y_out, z, z_loc_next, g_next


def pp_bwd_step(delta, h_sum, L, C, z_prev, D_prev):
    """bwd_combine(l) fused with bwd_compress(l-1) — the inter-collective
    segment between two backward Reduce-Scatters."""
    delta_prev = pp_bwd_combine(delta, h_sum, L, C, z_prev)
    h_out_prev = pp_bwd_compress(delta_prev, D_prev)
    return delta_prev, h_out_prev


def make_pp_loss_step(scale: float):
    """mse_delta fused with the top layer's bwd_compress."""

    def pp_loss_step(y_out, z, target, D):
        loss_local, delta = ref.mse_delta(y_out, z, target, scale)
        h_out = pp_bwd_compress(delta, D)
        return loss_local, delta, h_out

    return pp_loss_step


def tp_bwd_step(dy_shard, z_prev, y_full_prev):
    """tp_bwd_finish fused with the next layer's tp_grads."""
    delta_prev = ref.tp_bwd_finish(dy_shard, z_prev)
    dW, db = ref.tp_grads(y_full_prev, delta_prev)
    return delta_prev, dW, db


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def make_mse_delta(scale: float):
    """MSE loss segment with the 1/(B*n) gradient scale baked in.

    The scale is a compile-time constant (aot.py bakes one per artifact
    config) so the lowered module has no scalar input plumbing.
    """

    def mse_delta(y_out, z, target):
        return ref.mse_delta(y_out, z, target, scale)

    return mse_delta
