"""Pallas kernel for the tensor-parallel baseline shard GEMM (L1).

The TP per-rank forward hot-spot is z = y_full @ W + b with y_full [B, n]
(the post-All-Gather full activation) and W [n, np_] (the column shard).
Unlike the phantom kernels this is one large MXU-friendly GEMM — the paper's
point is precisely that TP pays O(n^2/p) FLOPs *and* O(n) bytes on the wire
where PP pays O(n^2/p^2 + kn/p) and O(k).

Grid: (B/bB, n/bK) with K-accumulation into the output block, the same
canonical TPU matmul pattern as phantom.fused_local_compress.
interpret=True for CPU PJRT (see phantom.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .phantom import LANE, _tile


def _tp_shard_matmul_kernel(y_ref, w_ref, b_ref, z_ref):
    """y_ref: [bB, bK]  w_ref: [bK, np_]  b_ref: [np_]  z_ref: [bB, np_]."""
    kstep = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        z_ref[...] = jnp.broadcast_to(b_ref[...][None, :], z_ref.shape)

    z_ref[...] += jnp.dot(
        y_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def tp_shard_matmul(y_full, W, b, *, b_tile=None, k_tile=None):
    """z = y_full @ W + b over the column shard W [n, np_]."""
    B, n = y_full.shape
    np_ = W.shape[1]
    bB = b_tile or _tile(B, 64)
    bK = k_tile or _tile(n, LANE)
    grid = (B // bB, n // bK)
    return pl.pallas_call(
        functools.partial(_tp_shard_matmul_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bK), lambda i, j: (i, j)),
            pl.BlockSpec((bK, np_), lambda i, j: (j, 0)),
            pl.BlockSpec((np_,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bB, np_), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, np_), jnp.float32),
        interpret=True,
    )(y_full, W, b)
