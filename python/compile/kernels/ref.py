"""Pure-jnp reference oracles for the phantom-parallel per-rank operators.

These are the ground truth the Pallas kernels (phantom.py, tp.py) are tested
against, and the numerically identical "fast path" that aot.py lowers for the
Rust runtime (XLA fuses these to plain dot ops, which run much faster on the
CPU PJRT backend than interpret-mode Pallas loops; the Pallas variants are
lowered alongside them and exercised by tests and the --pallas artifact set).

Shape conventions (batch-major, matching the Rust coordinator):
    B  : batch size
    np_: n / p, the per-rank shard width (``np`` shadows numpy, hence np_)
    k  : phantom (ghost-neuron) width, k << np_
    p  : number of ranks

    y      : [B, np_]      local activation shard
    L      : [np_, np_]    local update matrix      (paper: L_l^(j))
    C      : [np_, k]      compressor               (paper: C_l^(j), transposed)
    D      : [p, k, np_]   stacked decompressors    (paper: D_l^(i,j)); the
                           slot belonging to the local rank is ZERO and its
                           g_all slot is zeroed by the coordinator after the
                           All-Gather, so no masking appears in the math.
    g_all  : [p, B, k]     gathered phantom activations (own slot zeroed)
    b      : [np_]         bias
"""

from __future__ import annotations

import jax.numpy as jnp


def relu(x):
    return jnp.maximum(x, 0.0)


def drelu(z):
    """Derivative of ReLU evaluated at the pre-activation z."""
    return (z > 0.0).astype(z.dtype)


# ---------------------------------------------------------------------------
# Phantom-parallel forward (paper Eqn. 11)
# ---------------------------------------------------------------------------

def pp_fwd_local(y, L, C):
    """Local update + compression: the per-rank forward hot-spot.

    Returns (z_loc, g) where
        z_loc = y @ L        [B, np_]   (local update)
        g     = y @ C        [B, k]     (phantom layer, k ghost neurons)
    """
    return y @ L, y @ C


def pp_fwd_combine(z_loc, g_all, D, b):
    """Decompress-and-accumulate the gathered phantom layers.

    z     = z_loc + sum_i g_all[i] @ D[i] + b     [B, np_]
    y_out = relu(z)

    The local rank's slot of g_all is zero, so the i != j restriction of
    Eqn. (11) holds without masking.
    Returns (y_out, z); z is kept for sigma'(z) in the backward pass.
    """
    z = z_loc + jnp.einsum("pbk,pkm->bm", g_all, D) + b[None, :]
    return relu(z), z


# ---------------------------------------------------------------------------
# Phantom-parallel backward (paper Eqns. 15-21)
# ---------------------------------------------------------------------------

def pp_bwd_compress(delta, D):
    """Per-destination compressed errors h (paper Eqn. 17, under-brace term).

    h_out[i] = delta @ D[i].T    [p, B, k]

    h_out[i] is the contribution of this rank to destination rank i; the
    Reduce-Scatter collective sums slot i across ranks and delivers the sum
    to rank i.
    """
    return jnp.einsum("bm,pkm->pbk", delta, D)


def pp_bwd_combine(delta_next, h_sum, L, C, z_prev):
    """Backpropagate the local error one layer (paper Eqn. 17).

    delta_prev = (delta_next @ L.T + h_sum @ C.T) * relu'(z_prev)
    """
    return (delta_next @ L.T + h_sum @ C.T) * drelu(z_prev)


def pp_grads(y_prev, delta, h_sum, g_all):
    """Parameter gradients (paper Eqns. 18-21), batch-summed.

    dL = y_prev.T @ delta            [np_, np_]
    dC = y_prev.T @ h_sum            [np_, k]
    dD[i] = g_all[i].T @ delta       [p, k, np_]  (own slot auto-zero)
    db = sum_B delta                 [np_]
    """
    dL = y_prev.T @ delta
    dC = y_prev.T @ h_sum
    dD = jnp.einsum("pbk,bm->pkm", g_all, delta)
    db = delta.sum(axis=0)
    return dL, dC, dD, db


# ---------------------------------------------------------------------------
# Loss (sharded MSE, paper Eqns. 14-16)
# ---------------------------------------------------------------------------

def mse_delta(y_out, z, target, scale):
    """Local shard of the additive MSE loss and its pre-activation error.

    loss_local = sum((y_out - target)^2)          (rank-local partial sum;
                                                   the coordinator divides by
                                                   B*n after summing ranks)
    delta_L    = 2*scale*(y_out - target)*relu'(z)   with scale = 1/(B*n)
    """
    diff = y_out - target
    loss_local = jnp.sum(diff * diff)
    delta = (2.0 * scale) * diff * drelu(z)
    return loss_local, delta


# ---------------------------------------------------------------------------
# Tensor-parallel baseline (paper Sec. II-B / Table II)
# ---------------------------------------------------------------------------

def tp_fwd(y_full, W, b):
    """TP forward: full activation (post All-Gather) times the column shard.

    z = y_full @ W + b    [B, np_]    W: [n, np_]
    Returns (y_out, z).
    """
    z = y_full @ W + b[None, :]
    return relu(z), z


def tp_bwd_partial(delta, W):
    """TP backward partial: this rank's contribution to d y_full.

    dy_full_partial = delta @ W.T    [B, n]
    All-Reduce (or Reduce-Scatter) across ranks completes the sum.
    """
    return delta @ W.T


def tp_bwd_finish(dy_shard, z_prev):
    """Apply the activation derivative to the reduced shard."""
    return dy_shard * drelu(z_prev)


def tp_grads(y_full, delta):
    """TP weight/bias gradients: dW = y_full.T @ delta, db = sum_B delta."""
    return y_full.T @ delta, delta.sum(axis=0)


# ---------------------------------------------------------------------------
# Monolithic dense-equivalents (test oracles only, never lowered)
# ---------------------------------------------------------------------------

def pp_dense_layer(y_full, Ls, Cs, Ds, bs):
    """Single-rank evaluation of one phantom layer over the FULL width.

    y_full: [B, n]; Ls: [p, np_, np_]; Cs: [p, np_, k]; Ds: [p, p, k, np_]
    (Ds[j, i] is rank j's decompressor for source rank i; Ds[j, j] == 0);
    bs: [p, np_]. Returns (y_out_full [B, n], z_full [B, n]).
    """
    p, np_, _ = Ls.shape
    B = y_full.shape[0]
    shards = y_full.reshape(B, p, np_).transpose(1, 0, 2)       # [p, B, np_]
    g = jnp.einsum("jbm,jmk->jbk", shards, Cs)                  # [p, B, k]
    z = jnp.einsum("jbm,jmn->jbn", shards, Ls)                  # local update
    z = z + jnp.einsum("ibk,jikn->jbn", g, Ds)                  # decompress
    z = z + bs[:, None, :]
    z_full = z.transpose(1, 0, 2).reshape(B, p * np_)
    return relu(z_full), z_full


def tp_dense_layer(y_full, W_full, b_full):
    """Single-rank evaluation of one TP layer: y = relu(y @ W + b)."""
    z = y_full @ W_full + b_full[None, :]
    return relu(z), z
