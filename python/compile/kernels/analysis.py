"""L1 structural analysis: VMEM footprint + MXU-utilization estimates per
BlockSpec (the TPU perf proxy — interpret=True gives CPU-numpy timings only,
so kernel optimization targets STRUCTURE; see DESIGN.md §3 and
EXPERIMENTS.md §Perf).

Run as a module to print the table:
    python -m compile.kernels.analysis
"""

from __future__ import annotations

from dataclasses import dataclass

# TPU v4-ish core budget used for the estimates.
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128


@dataclass
class KernelEstimate:
    name: str
    grid: tuple
    vmem_bytes: int
    # Fraction of MXU lanes fed by the smallest contraction tile.
    mxu_utilization: float
    # HBM bytes read per grid step (double-buffered streams).
    hbm_read_bytes: int

    @property
    def fits_vmem(self) -> bool:
        # double buffering doubles the streamed-input footprint
        return 2 * self.vmem_bytes <= VMEM_BYTES


def _tile(dim: int, pref: int) -> int:
    t = min(dim, pref)
    while dim % t:
        t -= 1
    return t


def fused_local_compress(B: int, m: int, k: int) -> KernelEstimate:
    """One pass over y tiles feeding BOTH z=y@L and g=y@C accumulators."""
    bB, bK = _tile(B, 64), _tile(m, MXU_DIM)
    # resident per step: y tile + L K-slab + C K-slab + both accumulators
    vmem = 4 * (bB * bK + bK * m + bK * k + bB * m + bB * k)
    mxu = min(bK, MXU_DIM) / MXU_DIM * min(bB, MXU_DIM) / MXU_DIM
    hbm = 4 * (bB * bK + bK * m + bK * k)
    return KernelEstimate(
        "fused_local_compress", (B // bB, m // bK), vmem, mxu, hbm
    )


def decompress_accum(B: int, m: int, k: int, p: int) -> KernelEstimate:
    """Per-source accumulation in VMEM scratch: the (p-1) k-wide partial
    products never round-trip to HBM (the GPU implementation writes each
    decompressor output to HBM and sums)."""
    bB = _tile(B, 64)
    vmem = 4 * (bB * m + bB * k + k * m + m)
    mxu = min(k, MXU_DIM) / MXU_DIM * min(bB, MXU_DIM) / MXU_DIM
    hbm = 4 * (bB * k + k * m)
    return KernelEstimate("decompress_accum", (B // bB, p), vmem, mxu, hbm)


def error_compress(B: int, m: int, k: int, p: int) -> KernelEstimate:
    bB = _tile(B, 64)
    vmem = 4 * (bB * m + k * m + bB * k)
    mxu = min(m, MXU_DIM) / MXU_DIM * min(bB, MXU_DIM) / MXU_DIM
    hbm = 4 * (bB * m + k * m)
    return KernelEstimate("error_compress", (p, B // bB), vmem, mxu, hbm)


def analyze(B: int, m: int, k: int, p: int):
    return [
        fused_local_compress(B, m, k),
        decompress_accum(B, m, k, p),
        error_compress(B, m, k, p),
    ]


def main():
    # paper-scale per-rank shapes: n=16,384 p=8 -> m=2048; Fig-6 scale m=512
    for (B, m, k, p) in [(32, 2048, 16, 8), (32, 512, 64, 256), (16, 1024, 32, 8)]:
        print(f"\n== B={B} m={m} k={k} p={p} ==")
        print(f"{'kernel':>22s} {'grid':>12s} {'VMEM':>10s} {'fits':>5s} {'MXU util':>9s}")
        for e in analyze(B, m, k, p):
            print(
                f"{e.name:>22s} {str(e.grid):>12s} {e.vmem_bytes/1024:>9.1f}K "
                f"{str(e.fits_vmem):>5s} {e.mxu_utilization:>8.1%}"
            )


if __name__ == "__main__":
    main()
