"""Pallas kernels for the phantom-parallel per-rank hot-spots (L1).

Three kernels cover the paper's per-rank compute (Sec. IV):

  * ``fused_local_compress``  — forward local update + compression,
      z_loc = y @ L  and  g = y @ C  fused into ONE pass over the activation
      tiles: y is read from HBM once and feeds both the MXU contraction with
      L and the (skinny) contraction with C. On a real TPU this halves the
      activation HBM traffic of the forward hot path; the paper's GPU
      implementation pays two kernel launches + two reads.
  * ``decompress_accum``      — forward remote update,
      z = z_loc + sum_i g_all[i] @ D[i] + b; tiles over the n/p axis and
      keeps the accumulator in VMEM scratch so the (p-1) small-k partial
      products never round-trip to HBM (the small-GEMM problem the paper
      attributes its p=256 flip-flop to; see DESIGN.md §Hardware-Adaptation).
  * ``error_compress``        — backward error compression,
      h_out[i] = delta @ D[i].T, the Reduce-Scatter payload of Eqn. 17.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness vehicle and the
BlockSpec structure is the TPU-optimization artifact (VMEM footprint / MXU
utilization estimates live in EXPERIMENTS.md §Perf).

Grid conventions: the K-reduction dimension (n/p) is the innermost grid
axis; outputs are accumulated in place across K steps with an @pl.when
zero-init at step 0 — the canonical TPU matmul pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Tile sizes. 128 matches the MXU systolic-array edge; batch tiles are
# clamped to the actual batch. Shapes used by the coordinator are multiples
# of these (shapes.py guarantees it); tests sweep ragged shapes through the
# jnp reference instead.
LANE = 128


def _tile(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (tile must divide dim)."""
    t = min(dim, pref)
    while dim % t != 0:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# fused local update + compression
# ---------------------------------------------------------------------------

def _fused_local_compress_kernel(y_ref, l_ref, c_ref, z_ref, g_ref, *, nsteps):
    """Grid (B/bB, np/bK): K-step accumulation into both outputs.

    y_ref: [bB, bK]  l_ref: [bK, np_]  c_ref: [bK, k]
    z_ref: [bB, np_] g_ref: [bB, k]
    """
    kstep = pl.program_id(1)

    @pl.when(kstep == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    y = y_ref[...]
    z_ref[...] += jnp.dot(y, l_ref[...], preferred_element_type=jnp.float32)
    g_ref[...] += jnp.dot(y, c_ref[...], preferred_element_type=jnp.float32)
    del nsteps  # documented for BlockSpec readers; grid carries it


def fused_local_compress(y, L, C, *, b_tile=None, k_tile=None):
    """z_loc = y @ L and g = y @ C in one fused pass (see module docstring)."""
    B, np_ = y.shape
    k = C.shape[1]
    bB = b_tile or _tile(B, 64)
    bK = k_tile or _tile(np_, LANE)
    nsteps = np_ // bK
    grid = (B // bB, nsteps)
    return pl.pallas_call(
        functools.partial(_fused_local_compress_kernel, nsteps=nsteps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bK), lambda i, j: (i, j)),     # y tile
            pl.BlockSpec((bK, np_), lambda i, j: (j, 0)),    # L K-slab
            pl.BlockSpec((bK, k), lambda i, j: (j, 0)),      # C K-slab
        ],
        out_specs=[
            pl.BlockSpec((bB, np_), lambda i, j: (i, 0)),    # z accumulator
            pl.BlockSpec((bB, k), lambda i, j: (i, 0)),      # g accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, np_), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=True,
    )(y, L, C)


# ---------------------------------------------------------------------------
# decompress + accumulate (remote update)
# ---------------------------------------------------------------------------

def _decompress_accum_kernel(zloc_ref, g_ref, d_ref, b_ref, z_ref, *, p):
    """Grid (B/bB, p): accumulate one source rank's decompression per step.

    zloc_ref: [bB, np_]  g_ref: [1, bB, k]  d_ref: [1, k, np_]
    b_ref: [np_]         z_ref: [bB, np_]
    """
    src = pl.program_id(1)

    @pl.when(src == 0)
    def _init():
        z_ref[...] = zloc_ref[...] + b_ref[...][None, :]

    z_ref[...] += jnp.dot(
        g_ref[0], d_ref[0], preferred_element_type=jnp.float32
    )
    del p


def decompress_accum(z_loc, g_all, D, b, *, b_tile=None):
    """z = z_loc + sum_i g_all[i] @ D[i] + b   (own slot of g_all is zero).

    Returns the pre-activation z; the caller applies the activation (kept
    separate so the same kernel serves forward and the z-stash for backward).
    """
    p, B, k = g_all.shape
    np_ = z_loc.shape[1]
    bB = b_tile or _tile(B, 64)
    grid = (B // bB, p)
    return pl.pallas_call(
        functools.partial(_decompress_accum_kernel, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, np_), lambda i, s: (i, 0)),      # z_loc
            pl.BlockSpec((1, bB, k), lambda i, s: (s, i, 0)),  # g_all[src]
            pl.BlockSpec((1, k, np_), lambda i, s: (s, 0, 0)), # D[src]
            pl.BlockSpec((np_,), lambda i, s: (0,)),           # bias
        ],
        out_specs=pl.BlockSpec((bB, np_), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, np_), jnp.float32),
        interpret=True,
    )(z_loc, g_all, D, b)


# ---------------------------------------------------------------------------
# backward error compression
# ---------------------------------------------------------------------------

def _error_compress_kernel(delta_ref, d_ref, h_ref):
    """Grid (p, B/bB): h[dest] = delta @ D[dest].T, one dest per grid step.

    delta_ref: [bB, np_]  d_ref: [1, k, np_]  h_ref: [1, bB, k]
    """
    h_ref[0, ...] = jax.lax.dot_general(
        delta_ref[...],
        d_ref[0],
        # contract delta's np_ axis (1) with D's np_ axis (1): delta @ D.T
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def error_compress(delta, D, *, b_tile=None):
    """h_out[i] = delta @ D[i].T — the k-width Reduce-Scatter payload."""
    p, k, np_ = D.shape
    B = delta.shape[0]
    bB = b_tile or _tile(B, 64)
    grid = (p, B // bB)
    return pl.pallas_call(
        _error_compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, np_), lambda s, i: (i, 0)),      # delta
            pl.BlockSpec((1, k, np_), lambda s, i: (s, 0, 0)), # D[dest]
        ],
        out_specs=pl.BlockSpec((1, bB, k), lambda s, i: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, B, k), jnp.float32),
        interpret=True,
    )(delta, D)
