"""Artifact configuration set shared by aot.py, the tests, and the Makefile.

A config is one static-shape instantiation of the per-rank step functions:
(p ranks, global width n, ghost width k, batch B). The Rust runtime selects
a config by these four integers plus the kernel variant ("jnp" — the
XLA-fused fast path — or "pallas" — the L1 interpret-mode kernels).

Keep this list in sync with rust/src/config/presets.rs (the Rust side only
*reads* the manifest, so adding a config here is enough to make it loadable).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ArtifactConfig:
    name: str
    p: int          # rank count
    n: int          # global layer width (n % p == 0)
    k: int          # ghost neurons per phantom layer (k < n/p, Eqn. 8)
    batch: int      # per-iteration batch size
    variant: str    # "jnp" | "pallas"

    @property
    def np_(self) -> int:
        return self.n // self.p

    @property
    def scale(self) -> float:
        """Gradient scale for the global-mean MSE: 1/(B*n)."""
        return 1.0 / (self.batch * self.n)

    def validate(self) -> None:
        assert self.n % self.p == 0, f"{self.name}: n must divide by p"
        assert self.k < self.np_, f"{self.name}: Eqn. 8 requires k < n/p"
        assert self.variant in ("jnp", "pallas")


def _cfg(name, p, n, k, batch, variant="jnp"):
    c = ArtifactConfig(name, p, n, k, batch, variant)
    c.validate()
    return c


# The default artifact set. Names encode the role:
#   tiny*      — unit/integration test shapes (both variants)
#   quickstart — examples/quickstart.rs
#   small*     — Table-I / Fig-7 style measured convergence sweeps
#   e2e        — examples/train_ffn_e2e.rs (~134M-param TP-equivalent FFN)
CONFIGS = [
    _cfg("tiny", p=4, n=64, k=4, batch=8),
    _cfg("tiny_pallas", p=4, n=64, k=4, batch=8, variant="pallas"),
    _cfg("tiny_p2", p=2, n=32, k=4, batch=4),
    _cfg("tiny_p2_pallas", p=2, n=32, k=4, batch=4, variant="pallas"),
    _cfg("quickstart", p=4, n=256, k=8, batch=16),
    # measured convergence sweep: fixed n=1024, varying p and k
    _cfg("small", p=8, n=1024, k=16, batch=32),
    _cfg("small_k4", p=8, n=1024, k=4, batch=32),
    _cfg("small_k8", p=8, n=1024, k=8, batch=32),
    _cfg("small_k32", p=8, n=1024, k=32, batch=32),
    _cfg("small_p2", p=2, n=1024, k=16, batch=32),
    _cfg("small_p4", p=4, n=1024, k=16, batch=32),
    # medium: Fig-5b-style measured anchor (n=2048)
    _cfg("medium", p=8, n=2048, k=16, batch=32),
    # end-to-end driver: TP model is 2*8192^2 = 134M parameters
    _cfg("e2e", p=8, n=8192, k=32, batch=16),
]

BY_NAME = {c.name: c for c in CONFIGS}

# Entry points lowered per config (function name in compile.model).
PP_ENTRIES = [
    "pp_fwd_local",
    "pp_fwd_combine",
    "pp_bwd_compress",
    "pp_bwd_combine",
    "pp_grads",
    # fused inter-collective segments (perf pass; EXPERIMENTS.md §Perf)
    "pp_fwd_step",
    "pp_bwd_step",
    "pp_loss_step",
]
TP_ENTRIES = [
    "tp_fwd",
    "tp_bwd_partial",
    "tp_bwd_finish",
    "tp_grads",
    "tp_bwd_step",
]
SHARED_ENTRIES = ["mse_delta"]
ALL_ENTRIES = PP_ENTRIES + TP_ENTRIES + SHARED_ENTRIES
