"""AOT lowering: JAX step functions -> HLO text artifacts + manifest.json.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the xla_extension 0.5.1
bundled with the Rust ``xla`` crate rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and /opt/xla-example/gen_hlo.py.

Everything is lowered with ``return_tuple=True`` so every module's root is a
tuple; the Rust runtime unwraps it uniformly.

Usage (from python/):
    python -m compile.aot --out ../artifacts [--configs tiny,small] [--force]

Lowering is pure tracing (no XLA compilation happens here); the Rust runtime
compiles lazily via PJRT and caches executables.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .shapes import ALL_ENTRIES, CONFIGS, ArtifactConfig

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def entry_specs(cfg: ArtifactConfig, entry: str):
    """Input ShapeDtypeStructs for one entry point under one config."""
    B, n, k, p, m = cfg.batch, cfg.n, cfg.k, cfg.p, cfg.np_
    table = {
        # phantom parallelism
        "pp_fwd_local": (spec(B, m), spec(m, m), spec(m, k)),
        "pp_fwd_combine": (spec(B, m), spec(p, B, k), spec(p, k, m), spec(m)),
        "pp_bwd_compress": (spec(B, m), spec(p, k, m)),
        "pp_bwd_combine": (spec(B, m), spec(B, k), spec(m, m), spec(m, k), spec(B, m)),
        "pp_grads": (spec(B, m), spec(B, m), spec(B, k), spec(p, B, k)),
        # tensor parallelism
        "tp_fwd": (spec(B, n), spec(n, m), spec(m)),
        "tp_bwd_partial": (spec(B, m), spec(n, m)),
        "tp_bwd_finish": (spec(B, m), spec(B, m)),
        "tp_grads": (spec(B, n), spec(B, m)),
        # fused segments (perf pass)
        "pp_fwd_step": (
            spec(B, m), spec(p, B, k), spec(p, k, m), spec(m), spec(m, m), spec(m, k),
        ),
        "pp_bwd_step": (
            spec(B, m), spec(B, k), spec(m, m), spec(m, k), spec(B, m), spec(p, k, m),
        ),
        "pp_loss_step": (spec(B, m), spec(B, m), spec(B, m), spec(p, k, m)),
        "tp_bwd_step": (spec(B, m), spec(B, m), spec(B, n)),
        # shared
        "mse_delta": (spec(B, m), spec(B, m), spec(B, m)),
    }
    return table[entry]


def entry_fn(cfg: ArtifactConfig, entry: str):
    """The traced callable for one entry point (tuple-returning)."""
    if entry == "mse_delta":
        fn = model.make_mse_delta(cfg.scale)
    elif entry == "pp_loss_step":
        fn = model.make_pp_loss_step(cfg.scale)
    else:
        fn = getattr(model, entry)

    def tupled(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return tupled


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(cfg: ArtifactConfig, entry: str) -> str:
    model.use_pallas(cfg.variant == "pallas")
    try:
        lowered = jax.jit(entry_fn(cfg, entry)).lower(*entry_specs(cfg, entry))
        return to_hlo_text(lowered)
    finally:
        model.use_pallas(False)


def inputs_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip no-ops."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--configs", default="", help="comma-separated config names (default: all)")
    ap.add_argument("--force", action="store_true", help="relower even if fingerprint matches")
    args = ap.parse_args()

    wanted = set(filter(None, args.configs.split(",")))
    configs = [c for c in CONFIGS if not wanted or c.name in wanted]
    if wanted and len(configs) != len(wanted):
        missing = wanted - {c.name for c in configs}
        print(f"unknown config(s): {sorted(missing)}", file=sys.stderr)
        return 2

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    fp = inputs_fingerprint()

    if not args.force and not wanted and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("fingerprint") == fp:
            print(f"artifacts up to date (fingerprint {fp[:12]}); skipping")
            return 0

    manifest = {"version": 1, "fingerprint": fp, "configs": []}
    total = 0
    for cfg in configs:
        entries = {}
        for entry in ALL_ENTRIES:
            fname = f"{entry}__{cfg.name}.hlo.txt"
            text = lower_entry(cfg, entry)
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            entries[entry] = fname
            total += 1
            print(f"  lowered {cfg.name:>14s} / {entry:<16s} -> {fname} ({len(text)} B)")
        manifest["configs"].append(
            {
                "name": cfg.name,
                "p": cfg.p,
                "n": cfg.n,
                "k": cfg.k,
                "batch": cfg.batch,
                "np": cfg.np_,
                "scale": cfg.scale,
                "variant": cfg.variant,
                "entries": entries,
            }
        )

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {total} modules + manifest to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
