"""AOT pipeline gate: lowering produces parseable HLO text and a manifest
that matches the config set; the fingerprint makes `make artifacts` a no-op.
"""

from __future__ import annotations

import json
import os

import pytest

from compile import aot
from compile.shapes import ALL_ENTRIES, BY_NAME, CONFIGS


def test_config_set_is_valid():
    names = [c.name for c in CONFIGS]
    assert len(names) == len(set(names)), "duplicate config names"
    for c in CONFIGS:
        c.validate()
        # paper Eqn. 8: PP is only smaller than TP when k < (n/p)(1 - 1/p)
        assert c.k < (c.n / c.p) * (1 - 1 / c.p), c.name


@pytest.mark.parametrize("entry", ALL_ENTRIES)
def test_lower_tiny_entry_produces_hlo_text(entry):
    text = aot.lower_entry(BY_NAME["tiny"], entry)
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    # return_tuple=True: the entry computation must return a tuple
    assert "tuple(" in text or ") tuple" in text or "(f32[" in text


def test_lower_pallas_variant_differs_but_parses():
    jnp_text = aot.lower_entry(BY_NAME["tiny"], "pp_fwd_local")
    pal_text = aot.lower_entry(BY_NAME["tiny_pallas"], "pp_fwd_local")
    assert pal_text.startswith("HloModule")
    # interpret-mode pallas lowers to a loopy module, not a single fused dot
    assert jnp_text != pal_text


def test_entry_specs_cover_all_entries():
    cfg = BY_NAME["tiny"]
    for entry in ALL_ENTRIES:
        specs = aot.entry_specs(cfg, entry)
        assert all(s.dtype.name == "float32" for s in specs)


def test_fingerprint_is_stable():
    assert aot.inputs_fingerprint() == aot.inputs_fingerprint()


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", str(out), "--configs", "tiny_p2"]
    try:
        assert aot.main() == 0
    finally:
        sys.argv = argv
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    (cfg,) = manifest["configs"]
    assert cfg["name"] == "tiny_p2"
    assert cfg["np"] == cfg["n"] // cfg["p"]
    for entry, fname in cfg["entries"].items():
        assert entry in ALL_ENTRIES
        path = out / fname
        assert path.exists()
        assert path.read_text().startswith("HloModule")
