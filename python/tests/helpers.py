"""Shared test utilities: parameter construction and a numpy-side simulation
of the p-rank coordinator (collectives included) built from the per-rank
step functions. This mirrors rust/src/coordinator exactly; the Rust
integration tests assert the same invariants end-to-end through PJRT.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile.kernels import ref
from compile import model


def make_pp_params(rng, L, p, m, k, scale=0.2):
    """Random phantom-parallel parameters.

    Returns dict with:
      Ls: [L, p, m, m]   Cs: [L, p, m, k]   bs: [L, p, m]
      Ds: [L, p, p, k, m]  (Ds[l, j, i] = rank j's decompressor for source i,
                            Ds[l, j, j] = 0)
    """
    Ls = rng.normal(size=(L, p, m, m)).astype(np.float32) * scale / np.sqrt(m)
    Cs = rng.normal(size=(L, p, m, k)).astype(np.float32) * scale / np.sqrt(m)
    Ds = rng.normal(size=(L, p, p, k, m)).astype(np.float32) * scale / np.sqrt(k)
    for l in range(L):
        for j in range(p):
            Ds[l, j, j] = 0.0
    bs = rng.normal(size=(L, p, m)).astype(np.float32) * 0.01
    return {"Ls": Ls, "Cs": Cs, "Ds": Ds, "bs": bs}


def make_tp_params(rng, L, p, n, scale=0.2):
    """Random TP parameters: Ws: [L, n, n] (column shard j = W[:, j*m:(j+1)*m]),
    bs: [L, n]."""
    Ws = rng.normal(size=(L, n, n)).astype(np.float32) * scale / np.sqrt(n)
    bs = rng.normal(size=(L, n)).astype(np.float32) * 0.01
    return {"Ws": Ws, "bs": bs}


def shard(x, p):
    """[B, n] -> list of p shards [B, n/p]."""
    return np.split(np.asarray(x), p, axis=1)


def unshard(parts):
    return np.concatenate(parts, axis=1)


# ---------------------------------------------------------------------------
# Simulated p-rank phantom-parallel iteration (fwd + bwd)
# ---------------------------------------------------------------------------

def pp_forward_sim(params, x):
    """Run the p-rank PP forward using the step functions + numpy collectives.

    Returns (y_out_full, stash) where stash holds per-layer, per-rank
    activations needed for backward: ys[l][j], zs[l][j], g_alls[l][j].
    """
    Ls, Cs, Ds, bs = params["Ls"], params["Cs"], params["Ds"], params["bs"]
    L, p = Ls.shape[0], Ls.shape[1]
    ys = [shard(x, p)]        # ys[0] = input shards
    zs, g_alls = [], []
    for l in range(L):
        zlocs, gs = [], []
        for j in range(p):
            z_loc, g = model.pp_fwd_local(
                jnp.asarray(ys[l][j]), jnp.asarray(Ls[l, j]), jnp.asarray(Cs[l, j])
            )
            zlocs.append(np.asarray(z_loc))
            gs.append(np.asarray(g))
        gathered = np.stack(gs)                       # All-Gather [p, B, k]
        y_next, z_next, galls = [], [], []
        for j in range(p):
            g_all = gathered.copy()
            g_all[j] = 0.0                            # own slot zeroed
            y_out, z = model.pp_fwd_combine(
                jnp.asarray(zlocs[j]), jnp.asarray(g_all),
                jnp.asarray(Ds[l, j]), jnp.asarray(bs[l, j]),
            )
            y_next.append(np.asarray(y_out))
            z_next.append(np.asarray(z))
            galls.append(g_all)
        ys.append(y_next)
        zs.append(z_next)
        g_alls.append(galls)
    return unshard(ys[-1]), {"ys": ys, "zs": zs, "g_alls": g_alls}


def pp_backward_sim(params, stash, target):
    """Run the p-rank PP backward; returns (loss, grads) with grads shaped
    like params. Loss is the global mean((y-t)^2)."""
    Ls, Cs, Ds, bs = params["Ls"], params["Cs"], params["Ds"], params["bs"]
    L, p = Ls.shape[0], Ls.shape[1]
    ys, zs, g_alls = stash["ys"], stash["zs"], stash["g_alls"]
    B = ys[0][0].shape[0]
    n = p * ys[0][0].shape[1]
    scale = 1.0 / (B * n)
    t_shards = shard(target, p)

    mse = model.make_mse_delta(scale)
    deltas, loss_total = [], 0.0
    for j in range(p):
        ll, d = mse(
            jnp.asarray(ys[L][j]), jnp.asarray(zs[L - 1][j]), jnp.asarray(t_shards[j])
        )
        loss_total += float(ll)
        deltas.append(np.asarray(d))
    loss = loss_total * scale

    grads = {k: np.zeros_like(v) for k, v in params.items()}
    for l in range(L - 1, -1, -1):
        # error compression + Reduce-Scatter
        h_outs = [
            np.asarray(model.pp_bwd_compress(jnp.asarray(deltas[i]), jnp.asarray(Ds[l, i])))
            for i in range(p)
        ]
        h_sums = [sum(h_outs[i][j] for i in range(p)) for j in range(p)]
        # gradients
        for j in range(p):
            dL, dC, dD, db = model.pp_grads(
                jnp.asarray(ys[l][j]), jnp.asarray(deltas[j]),
                jnp.asarray(h_sums[j]), jnp.asarray(g_alls[l][j]),
            )
            grads["Ls"][l, j] = np.asarray(dL)
            grads["Cs"][l, j] = np.asarray(dC)
            # dD from pp_grads is [p, k, m] = d/dD[j, i] for each source i
            grads["Ds"][l, j] = np.asarray(dD)
            grads["bs"][l, j] = np.asarray(db)
        # propagate delta to layer l-1 (skip below the first layer)
        if l > 0:
            deltas = [
                np.asarray(model.pp_bwd_combine(
                    jnp.asarray(deltas[j]), jnp.asarray(h_sums[j]),
                    jnp.asarray(Ls[l, j]), jnp.asarray(Cs[l, j]),
                    jnp.asarray(zs[l - 1][j]),
                ))
                for j in range(p)
            ]
    return loss, grads


# ---------------------------------------------------------------------------
# Simulated p-rank TP iteration
# ---------------------------------------------------------------------------

def tp_forward_sim(params, x, p):
    Ws, bs = params["Ws"], params["bs"]
    L, n = Ws.shape[0], Ws.shape[1]
    m = n // p
    ys = [shard(x, p)]
    zs = []
    for l in range(L):
        y_full = unshard(ys[l])                       # All-Gather
        y_next, z_next = [], []
        for j in range(p):
            W = Ws[l][:, j * m:(j + 1) * m]
            y_out, z = model.tp_fwd(
                jnp.asarray(y_full), jnp.asarray(W), jnp.asarray(bs[l, j * m:(j + 1) * m])
            )
            y_next.append(np.asarray(y_out))
            z_next.append(np.asarray(z))
        ys.append(y_next)
        zs.append(z_next)
    return unshard(ys[-1]), {"ys": ys, "zs": zs}


def tp_backward_sim(params, stash, target, p):
    Ws, bs = params["Ws"], params["bs"]
    L, n = Ws.shape[0], Ws.shape[1]
    m = n // p
    ys, zs = stash["ys"], stash["zs"]
    B = ys[0][0].shape[0]
    scale = 1.0 / (B * n)
    t_shards = shard(target, p)

    mse = model.make_mse_delta(scale)
    deltas, loss_total = [], 0.0
    for j in range(p):
        ll, d = mse(
            jnp.asarray(ys[L][j]), jnp.asarray(zs[L - 1][j]), jnp.asarray(t_shards[j])
        )
        loss_total += float(ll)
        deltas.append(np.asarray(d))
    loss = loss_total * scale

    grads = {"Ws": np.zeros_like(Ws), "bs": np.zeros_like(bs)}
    for l in range(L - 1, -1, -1):
        y_full = unshard(ys[l])
        for j in range(p):
            dW, db = model.tp_grads(jnp.asarray(y_full), jnp.asarray(deltas[j]))
            grads["Ws"][l][:, j * m:(j + 1) * m] = np.asarray(dW)
            grads["bs"][l][j * m:(j + 1) * m] = np.asarray(db)
        if l > 0:
            # partial dy_full per rank, All-Reduce, slice own shard, * relu'
            partials = [
                np.asarray(model.tp_bwd_partial(
                    jnp.asarray(deltas[j]), jnp.asarray(Ws[l][:, j * m:(j + 1) * m])
                ))
                for j in range(p)
            ]
            dy_full = sum(partials)                   # All-Reduce
            deltas = [
                np.asarray(model.tp_bwd_finish(
                    jnp.asarray(dy_full[:, j * m:(j + 1) * m]),
                    jnp.asarray(zs[l - 1][j]),
                ))
                for j in range(p)
            ]
    return loss, grads


# ---------------------------------------------------------------------------
# Dense oracles over the same parameters
# ---------------------------------------------------------------------------

def pp_dense_forward(params, x):
    Ls, Cs, Ds, bs = params["Ls"], params["Cs"], params["Ds"], params["bs"]
    y = jnp.asarray(x)
    for l in range(Ls.shape[0]):
        y, _ = ref.pp_dense_layer(
            y, jnp.asarray(Ls[l]), jnp.asarray(Cs[l]), jnp.asarray(Ds[l]), jnp.asarray(bs[l])
        )
    return np.asarray(y)


def tp_dense_forward(params, x):
    Ws, bs = params["Ws"], params["bs"]
    y = jnp.asarray(x)
    for l in range(Ws.shape[0]):
        y, _ = ref.tp_dense_layer(y, jnp.asarray(Ws[l]), jnp.asarray(bs[l]))
    return np.asarray(y)
