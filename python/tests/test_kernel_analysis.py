"""L1 structural gates: every kernel's BlockSpec fits VMEM (with double
buffering) at all artifact-config scales and at paper scale, and the fused
kernel strictly reduces HBM activation traffic vs two separate GEMMs.
"""

from __future__ import annotations

from compile.kernels import analysis
from compile.shapes import CONFIGS


def test_all_artifact_configs_fit_vmem():
    for c in CONFIGS:
        for e in analysis.analyze(c.batch, c.np_, c.k, c.p):
            assert e.fits_vmem, f"{c.name}/{e.name}: {e.vmem_bytes} B"


def test_paper_scale_fits_vmem():
    # n=16,384 p=8 (Table I) and n=131,072 p=256 (Fig 6)
    for (B, m, k, p) in [(32, 2048, 16, 8), (32, 512, 64, 256)]:
        for e in analysis.analyze(B, m, k, p):
            assert e.fits_vmem, f"(B={B},m={m}): {e.name} {e.vmem_bytes} B"


def test_fused_kernel_saves_activation_traffic():
    """The fused local+compress kernel reads y once per K-step; two separate
    GEMM kernels would read it twice."""
    B, m, k = 32, 2048, 16
    fused = analysis.fused_local_compress(B, m, k)
    bB, bK = 32, 128
    y_tile_bytes = 4 * bB * bK
    # fused reads y once; unfused would add a second y stream
    unfused_hbm = fused.hbm_read_bytes + y_tile_bytes
    assert fused.hbm_read_bytes < unfused_hbm


def test_mxu_utilization_reflects_small_k_penalty():
    """decompress_accum is k-bound: at k=16 it feeds only 12.5% of the MXU
    rows — the structural root of the paper's small-GEMM observation [21]."""
    small_k = analysis.decompress_accum(32, 2048, 16, 8)
    big_k = analysis.decompress_accum(32, 2048, 128, 8)
    assert small_k.mxu_utilization < big_k.mxu_utilization
    assert abs(small_k.mxu_utilization - (16 / 128) * (32 / 128)) < 1e-9
