"""L1 gate: Pallas kernels (interpret mode) vs the pure-jnp oracle.

Hypothesis sweeps the kernels over shapes (batch, shard width, ghost width,
rank count) and input dtypes, asserting allclose against kernels/ref.py.
This is the CORE correctness signal for the L1 layer.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import phantom as pk
from compile.kernels import ref
from compile.kernels import tp as tpk

# Interpret-mode Pallas is slow; keep hypothesis example counts moderate and
# shapes small. Structure (tiling, accumulation order) is shape-independent.
COMMON = dict(deadline=None, max_examples=25)

dims = st.integers(min_value=1, max_value=24)
ranks = st.integers(min_value=2, max_value=5)
ghosts = st.integers(min_value=1, max_value=8)
import ml_dtypes
bfloat16 = ml_dtypes.bfloat16
dtypes = st.sampled_from([np.float32, bfloat16])


def _rand(rng, *shape, dtype=np.float32):
    return rng.normal(size=shape).astype(np.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == bfloat16 else dict(rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(B=dims, m=dims, k=ghosts, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_fused_local_compress_matches_ref(B, m, k, dtype, seed):
    rng = np.random.default_rng(seed)
    y = _rand(rng, B, m, dtype=dtype)
    L = _rand(rng, m, m, dtype=dtype)
    C = _rand(rng, m, k, dtype=dtype)
    z_pal, g_pal = pk.fused_local_compress(jnp.asarray(y), jnp.asarray(L), jnp.asarray(C))
    z_ref, g_ref = ref.pp_fwd_local(
        jnp.asarray(y, jnp.float32), jnp.asarray(L, jnp.float32), jnp.asarray(C, jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref), **_tol(dtype))


@settings(**COMMON)
@given(B=dims, m=dims, k=ghosts, p=ranks, seed=st.integers(0, 2**31 - 1))
def test_decompress_accum_matches_ref(B, m, k, p, seed):
    rng = np.random.default_rng(seed)
    z_loc = _rand(rng, B, m)
    g_all = _rand(rng, p, B, k)
    g_all[0] = 0.0  # own-slot convention
    D = _rand(rng, p, k, m)
    b = _rand(rng, m)
    z_pal = pk.decompress_accum(
        jnp.asarray(z_loc), jnp.asarray(g_all), jnp.asarray(D), jnp.asarray(b)
    )
    _y, z_ref = ref.pp_fwd_combine(
        jnp.asarray(z_loc), jnp.asarray(g_all), jnp.asarray(D), jnp.asarray(b)
    )
    np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref), rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(B=dims, m=dims, k=ghosts, p=ranks, seed=st.integers(0, 2**31 - 1))
def test_error_compress_matches_ref(B, m, k, p, seed):
    rng = np.random.default_rng(seed)
    delta = _rand(rng, B, m)
    D = _rand(rng, p, k, m)
    h_pal = pk.error_compress(jnp.asarray(delta), jnp.asarray(D))
    h_ref = ref.pp_bwd_compress(jnp.asarray(delta), jnp.asarray(D))
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref), rtol=2e-5, atol=2e-5)


@settings(**COMMON)
@given(B=dims, n=st.integers(2, 32), p=ranks, seed=st.integers(0, 2**31 - 1))
def test_tp_shard_matmul_matches_ref(B, n, p, seed):
    rng = np.random.default_rng(seed)
    m = max(1, n // p)
    y = _rand(rng, B, n)
    W = _rand(rng, n, m)
    b = _rand(rng, m)
    z_pal = tpk.tp_shard_matmul(jnp.asarray(y), jnp.asarray(W), jnp.asarray(b))
    _y, z_ref = ref.tp_fwd(jnp.asarray(y), jnp.asarray(W), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,m,k", [(8, 16, 4), (4, 128, 8), (16, 64, 16)])
def test_fused_kernel_mxu_aligned_shapes(B, m, k):
    """The artifact-config shapes (multiples of the tile sizes) in one place."""
    rng = np.random.default_rng(0)
    y, L, C = _rand(rng, B, m), _rand(rng, m, m), _rand(rng, m, k)
    z_pal, g_pal = pk.fused_local_compress(jnp.asarray(y), jnp.asarray(L), jnp.asarray(C))
    z_ref, g_ref = ref.pp_fwd_local(jnp.asarray(y), jnp.asarray(L), jnp.asarray(C))
    np.testing.assert_allclose(np.asarray(z_pal), np.asarray(z_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref), rtol=1e-5, atol=1e-5)


def test_tile_helper_divides():
    for dim in range(1, 300):
        t = pk._tile(dim, 128)
        assert 1 <= t <= min(dim, 128)
        assert dim % t == 0
