"""L2 gate: the hand-derived phantom/TP operators against autodiff ground truth.

Three layers of evidence, mirroring DESIGN.md §6:
  1. p-rank sharded forward == monolithic dense-equivalent forward.
  2. p-rank hand-derived backward (Eqns. 16-21) == jax.grad of the dense model.
  3. TP sharded pipeline == unsharded FFN (forward and backward).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from tests import helpers as H


# ---------------------------------------------------------------------------
# Forward equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,p,m,k,B", [(1, 2, 8, 2, 4), (2, 3, 8, 3, 5), (3, 4, 16, 4, 8)])
def test_pp_sharded_forward_equals_dense(L, p, m, k, B):
    rng = np.random.default_rng(7)
    params = H.make_pp_params(rng, L, p, m, k)
    x = rng.normal(size=(B, p * m)).astype(np.float32)
    y_sharded, _ = H.pp_forward_sim(params, x)
    y_dense = H.pp_dense_forward(params, x)
    np.testing.assert_allclose(y_sharded, y_dense, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("L,p,n,B", [(1, 2, 8, 4), (2, 4, 16, 8), (3, 2, 12, 5)])
def test_tp_sharded_forward_equals_dense(L, p, n, B):
    rng = np.random.default_rng(11)
    params = H.make_tp_params(rng, L, p, n)
    x = rng.normal(size=(B, n)).astype(np.float32)
    y_sharded, _ = H.tp_forward_sim(params, x, p)
    y_dense = H.tp_dense_forward(params, x)
    np.testing.assert_allclose(y_sharded, y_dense, rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=15)
@given(
    L=st.integers(1, 3), p=st.integers(2, 4), m=st.integers(2, 10),
    k=st.integers(1, 4), B=st.integers(1, 8), seed=st.integers(0, 2**31 - 1),
)
def test_pp_forward_equivalence_property(L, p, m, k, B, seed):
    rng = np.random.default_rng(seed)
    params = H.make_pp_params(rng, L, p, m, k)
    x = rng.normal(size=(B, p * m)).astype(np.float32)
    y_sharded, _ = H.pp_forward_sim(params, x)
    y_dense = H.pp_dense_forward(params, x)
    np.testing.assert_allclose(y_sharded, y_dense, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Backward: hand-derived Eqns. (16)-(21) vs jax.grad of the dense model
# ---------------------------------------------------------------------------

def _dense_loss(params_j, x, t):
    """Dense-equivalent PP model loss as a pure function of the param pytree."""
    y = x
    L = params_j["Ls"].shape[0]
    for l in range(L):
        y, _ = ref.pp_dense_layer(
            y, params_j["Ls"][l], params_j["Cs"][l], params_j["Ds"][l], params_j["bs"][l]
        )
    return jnp.mean((y - t) ** 2)


@pytest.mark.parametrize("L,p,m,k,B", [(1, 2, 6, 2, 4), (2, 3, 8, 3, 5), (2, 4, 8, 2, 6)])
def test_pp_backward_matches_autodiff(L, p, m, k, B):
    rng = np.random.default_rng(13)
    params = H.make_pp_params(rng, L, p, m, k)
    x = rng.normal(size=(B, p * m)).astype(np.float32)
    t = rng.normal(size=(B, p * m)).astype(np.float32)

    _, stash = H.pp_forward_sim(params, x)
    loss_manual, grads = H.pp_backward_sim(params, stash, t)

    params_j = {kk: jnp.asarray(v) for kk, v in params.items()}
    loss_auto, auto = jax.value_and_grad(_dense_loss)(params_j, jnp.asarray(x), jnp.asarray(t))

    assert abs(loss_manual - float(loss_auto)) < 1e-6 * max(1.0, abs(float(loss_auto)))
    for key in ("Ls", "Cs", "bs", "Ds"):
        got, want = grads[key], np.asarray(auto[key]).copy()
        if key == "Ds":
            # The diagonal slots Ds[l, j, j] are structurally FROZEN at zero
            # in the sharded system (own g_all slot is zeroed), so its grads
            # are zero there; autodiff of the dense oracle sees them as free
            # parameters that merely happen to hold zeros. Compare only the
            # trainable (off-diagonal) slots.
            for l in range(L):
                for j in range(p):
                    np.testing.assert_allclose(got[l, j, j], 0.0, atol=1e-7)
                    want[l, j, j] = 0.0
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5, err_msg=key)


def _tp_dense_loss(params_j, x, t):
    y = x
    for l in range(params_j["Ws"].shape[0]):
        y, _ = ref.tp_dense_layer(y, params_j["Ws"][l], params_j["bs"][l])
    return jnp.mean((y - t) ** 2)


@pytest.mark.parametrize("L,p,n,B", [(1, 2, 8, 4), (2, 4, 16, 6), (3, 2, 10, 5)])
def test_tp_backward_matches_autodiff(L, p, n, B):
    rng = np.random.default_rng(17)
    params = H.make_tp_params(rng, L, p, n)
    x = rng.normal(size=(B, n)).astype(np.float32)
    t = rng.normal(size=(B, n)).astype(np.float32)

    _, stash = H.tp_forward_sim(params, x, p)
    loss_manual, grads = H.tp_backward_sim(params, stash, t, p)

    params_j = {kk: jnp.asarray(v) for kk, v in params.items()}
    loss_auto, auto = jax.value_and_grad(_tp_dense_loss)(params_j, jnp.asarray(x), jnp.asarray(t))

    assert abs(loss_manual - float(loss_auto)) < 1e-6 * max(1.0, abs(float(loss_auto)))
    np.testing.assert_allclose(grads["Ws"], np.asarray(auto["Ws"]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(grads["bs"], np.asarray(auto["bs"]), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# One SGD step of the simulated pipeline reduces the loss (sanity, both modes)
# ---------------------------------------------------------------------------

def test_pp_sgd_step_reduces_loss():
    rng = np.random.default_rng(23)
    L, p, m, k, B = 2, 3, 8, 3, 16
    params = H.make_pp_params(rng, L, p, m, k)
    x = rng.normal(size=(B, p * m)).astype(np.float32)
    t = rng.normal(size=(B, p * m)).astype(np.float32) * 0.1

    _, stash = H.pp_forward_sim(params, x)
    loss0, grads = H.pp_backward_sim(params, stash, t)
    lr = 0.5
    stepped = {kk: params[kk] - lr * grads[kk] for kk in params}
    _, stash1 = H.pp_forward_sim(stepped, x)
    loss1, _ = H.pp_backward_sim(stepped, stash1, t)
    assert loss1 < loss0


def test_pallas_variant_matches_jnp_variant_end_to_end():
    """The full simulated iteration agrees between kernel variants."""
    from compile import model
    rng = np.random.default_rng(29)
    L, p, m, k, B = 2, 2, 8, 2, 4
    params = H.make_pp_params(rng, L, p, m, k)
    x = rng.normal(size=(B, p * m)).astype(np.float32)
    t = rng.normal(size=(B, p * m)).astype(np.float32)

    y_jnp, stash = H.pp_forward_sim(params, x)
    loss_jnp, grads_jnp = H.pp_backward_sim(params, stash, t)
    model.use_pallas(True)
    try:
        y_pal, stash_p = H.pp_forward_sim(params, x)
        loss_pal, grads_pal = H.pp_backward_sim(params, stash_p, t)
    finally:
        model.use_pallas(False)

    np.testing.assert_allclose(y_pal, y_jnp, rtol=1e-5, atol=1e-5)
    assert abs(loss_pal - loss_jnp) < 1e-6
    for key in grads_jnp:
        np.testing.assert_allclose(grads_pal[key], grads_jnp[key], rtol=1e-4, atol=1e-5)
